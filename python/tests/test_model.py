"""L2 model tests: forward/loss semantics, custom-VJP gradient routing,
train-step agreement across scatter backends, multi-step scan, naive
grads-export step."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import scatter_add as SK

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(vocab=128, dim=8, window=5, hidden=6)


def mk_batch(cfg, b, seed=0):
    rng = np.random.RandomState(seed)
    w = jnp.asarray(rng.randint(0, cfg.vocab, (b, cfg.window)), jnp.int32)
    c = jnp.asarray(rng.randint(0, cfg.vocab, b), jnp.int32)
    return w, c


def params(cfg=CFG, seed=0):
    return M.init_params(jax.random.PRNGKey(seed), cfg)


def test_param_shapes():
    p = params()
    for (name, shape), arr in zip(CFG.param_shapes(), p):
        assert arr.shape == shape, name
        assert arr.dtype == jnp.float32


def test_forward_shape_and_impl_invariance():
    p = params()
    w, _ = mk_batch(CFG, 16)
    s_rows = M.forward(p, w, impl="rows")
    s_native = M.forward(p, w, impl="native", use_pallas_hidden=False)
    assert s_rows.shape == (16,)
    np.testing.assert_allclose(s_rows, s_native, atol=1e-5)


def test_corrupt_windows_only_center():
    w, c = mk_batch(CFG, 8, seed=1)
    neg = M.corrupt_windows(w, c)
    center = CFG.window // 2
    assert np.array_equal(np.asarray(neg[:, center]), np.asarray(c))
    mask = np.ones(CFG.window, bool)
    mask[center] = False
    assert np.array_equal(np.asarray(neg[:, mask]), np.asarray(w[:, mask]))


def test_loss_nonnegative_and_at_margin_for_tied_scores():
    p = params()
    w, _ = mk_batch(CFG, 8, seed=2)
    # corrupt == original center -> s_pos == s_neg -> loss == margin
    c = w[:, CFG.window // 2]
    loss = M.loss_fn(p, w, c)
    assert float(loss) == pytest.approx(M.MARGIN, abs=1e-6)


def test_grad_routes_through_scatter_impl():
    """The custom VJP must produce the same embedding gradient as plain
    autodiff through jnp.take — for every scatter implementation."""
    p = params(seed=3)
    w, c = mk_batch(CFG, 8, seed=3)

    def plain_loss(pp):
        e, w1, b1, w2, b2 = pp

        def score(win):
            emb = jnp.take(e, win.reshape(-1), axis=0).reshape(win.shape[0], -1)
            h = jnp.tanh(emb @ w1 + b1)
            return (h @ w2 + b2)[:, 0]

        neg = M.corrupt_windows(w, c)
        return jnp.mean(jnp.maximum(0.0, M.MARGIN - score(w) + score(neg)))

    g_plain = jax.grad(plain_loss)(p)
    for impl in ["rows", "native", "naive"]:
        g = jax.grad(lambda pp: M.loss_fn(pp, w, c, impl=impl,
                                          use_pallas_hidden=False))(p)
        for a, b_ in zip(g, g_plain):
            np.testing.assert_allclose(a, b_, atol=1e-5)


def test_train_step_backends_agree():
    p = params(seed=4)
    w, c = mk_batch(CFG, 16, seed=4)
    out_rows = M.sgd_train_step(p, w, c, 0.05, impl="rows")
    out_native = M.sgd_train_step(p, w, c, 0.05, impl="native",
                                  use_pallas_hidden=False)
    for a, b_ in zip(out_rows, out_native):
        np.testing.assert_allclose(a, b_, atol=1e-5)


def test_train_step_decreases_loss_on_repeated_batch():
    p = params(seed=5)
    w, c = mk_batch(CFG, 32, seed=5)
    first = None
    for _ in range(25):
        *p, loss = M.sgd_train_step(tuple(p), w, c, 0.2)
        if first is None:
            first = float(loss)
    assert float(loss) < first


def test_multi_step_equals_sequential_steps():
    p = params(seed=6)
    k, b = 4, 8
    rng = np.random.RandomState(6)
    wk = jnp.asarray(rng.randint(0, CFG.vocab, (k, b, CFG.window)), jnp.int32)
    ck = jnp.asarray(rng.randint(0, CFG.vocab, (k, b)), jnp.int32)
    *p_multi, losses = M.sgd_train_multi(p, wk, ck, 0.1)
    p_seq = p
    seq_losses = []
    for i in range(k):
        *p_seq, loss = M.sgd_train_step(tuple(p_seq), wk[i], ck[i], 0.1)
        seq_losses.append(float(loss))
    for a, b_ in zip(p_multi, p_seq):
        np.testing.assert_allclose(a, b_, atol=1e-5)
    np.testing.assert_allclose(np.asarray(losses), seq_losses, atol=1e-5)


def test_naive_grad_step_composes_to_full_step():
    """Dense updates from naive_grad_step + host-side row application must
    reproduce the fused train step exactly (what gpu-naive relies on)."""
    p = params(seed=7)
    w, c = mk_batch(CFG, 8, seed=7)
    lr = 0.07
    w1n, b1n, w2n, b2n, idx_all, delta, loss_n = M.naive_grad_step(p, w, c, lr)
    e_updated = p[0].at[idx_all].add(delta)

    e_f, w1_f, b1_f, w2_f, b2_f, loss_f = M.sgd_train_step(p, w, c, lr,
                                                           impl="native")
    np.testing.assert_allclose(loss_n, loss_f, atol=1e-6)
    np.testing.assert_allclose(e_updated, e_f, atol=1e-5)
    np.testing.assert_allclose(w1n, w1_f, atol=1e-5)
    np.testing.assert_allclose(b1n, b1_f, atol=1e-5)
    np.testing.assert_allclose(w2n, w2_f, atol=1e-5)
    np.testing.assert_allclose(b2n, b2_f, atol=1e-5)


def test_naive_rows_applied_one_at_a_time():
    """Row-at-a-time application (the per-row dispatch path) equals the
    batched scatter, duplicates included."""
    p = params(seed=8)
    w, c = mk_batch(CFG, 4, seed=8)
    _, _, _, _, idx_all, delta, _ = M.naive_grad_step(p, w, c, 0.1)
    e_seq = p[0]
    for r in range(idx_all.shape[0]):
        e_seq = SK.scatter_row1(e_seq, idx_all[r : r + 1], delta[r : r + 1])
    np.testing.assert_allclose(e_seq, p[0].at[idx_all].add(delta), atol=1e-5)


def test_batch_loss_and_scores_signatures():
    p = params()
    w, c = mk_batch(CFG, 8)
    (loss,) = M.batch_loss(p, w, c)
    (s,) = M.scores(p, w)
    assert loss.shape == ()
    assert s.shape == (8,)


@settings(max_examples=15, deadline=None)
@given(b=st.sampled_from([1, 2, 8, 16]), seed=st.integers(0, 2**31 - 1),
       lr=st.floats(1e-4, 0.5))
def test_property_step_preserves_shapes_and_finiteness(b, seed, lr):
    p = params(seed=seed % 1000)
    w, c = mk_batch(CFG, b, seed=seed % 1000)
    out = M.sgd_train_step(p, w, c, lr)
    for (name, shape), arr in zip(CFG.param_shapes(), out[:5]):
        assert arr.shape == shape
        assert bool(jnp.all(jnp.isfinite(arr))), name
    assert np.isfinite(float(out[5]))


def test_sparse_step_equals_dense_step():
    """The perf-pass sparse-update step must be numerically identical to
    the dense-gradient step (all params, all backends)."""
    p = params(seed=9)
    w, c = mk_batch(CFG, 16, seed=9)
    for impl in ["rows", "native"]:
        dense = M.sgd_train_step(p, w, c, 0.07, impl=impl)
        sparse = M.sgd_train_step_sparse(p, w, c, 0.07, impl=impl)
        for a, b_ in zip(dense, sparse):
            np.testing.assert_allclose(a, b_, atol=1e-5)


def test_sparse_multi_equals_sequential_sparse():
    p = params(seed=10)
    k, b = 3, 8
    rng = np.random.RandomState(10)
    wk = jnp.asarray(rng.randint(0, CFG.vocab, (k, b, CFG.window)), jnp.int32)
    ck = jnp.asarray(rng.randint(0, CFG.vocab, (k, b)), jnp.int32)
    *pm, losses = M.sgd_train_multi_sparse(p, wk, ck, 0.1)
    ps = p
    for i in range(k):
        *ps, _ = M.sgd_train_step_sparse(tuple(ps), wk[i], ck[i], 0.1)
    for a, b_ in zip(pm, ps):
        np.testing.assert_allclose(a, b_, atol=1e-5)
