//! Scheduler stress: every committed artifact, repeatedly, at full
//! parallelism.
//!
//! The plan-level step scheduler's contract is *bitwise determinism*: it
//! may reorder step issue but never changes any step's inputs or any
//! kernel's geometry, so the scheduled threaded run must reproduce the
//! single-threaded tree-walk exactly — not within a tolerance. Repeated
//! runs shake out ordering races: with 8 threads and wide graphs the
//! actual interleaving differs run to run, and any missing dependency
//! edge (a mover racing a reader, an in-place write racing a consumer)
//! shows up as a flaky byte diff here long before it corrupts training.

use std::path::PathBuf;

use polyglot_gpu::backend::interp::plan::FuseMode;
use polyglot_gpu::backend::interp::InterpExecutable;
use polyglot_gpu::runtime::Manifest;
use polyglot_gpu::testkit::synth_artifact_inputs;
use polyglot_gpu::util::rng::Rng;
use xla::{ElementType, Literal};

const THREADS: usize = 8;
const RUNS: usize = 8;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Bitwise equality for array literals of either dtype; f32 compares by
/// bit pattern so `-0.0 != 0.0` and NaN payloads count as differences.
fn assert_bitwise(got: &Literal, want: &Literal, what: &str) {
    let (gs, ws) = (got.array_shape().unwrap(), want.array_shape().unwrap());
    assert_eq!(gs, ws, "{what}: shape");
    match gs.ty() {
        ElementType::F32 => {
            let g: Vec<u32> =
                got.to_vec::<f32>().unwrap().iter().map(|x| x.to_bits()).collect();
            let w: Vec<u32> =
                want.to_vec::<f32>().unwrap().iter().map(|x| x.to_bits()).collect();
            assert_eq!(g, w, "{what}: f32 bits");
        }
        _ => {
            assert_eq!(
                got.to_vec::<i32>().unwrap(),
                want.to_vec::<i32>().unwrap(),
                "{what}: i32"
            );
        }
    }
}

#[test]
fn every_artifact_is_bitwise_stable_under_the_scheduler() {
    let manifest = Manifest::load(&artifacts_dir()).unwrap();
    assert!(
        manifest.artifacts.len() >= 42,
        "stress floor: expected the full committed artifact set, found {}",
        manifest.artifacts.len()
    );
    let mut scheduled_wide = 0usize;
    for spec in &manifest.artifacts {
        let text = std::fs::read_to_string(&spec.file)
            .unwrap_or_else(|e| panic!("reading {}: {e}", spec.file.display()));
        let mut rng = Rng::new(0x5c4ed ^ spec.name.len() as u64);
        let inputs = synth_artifact_inputs(spec, &mut rng).unwrap();
        let refs: Vec<&Literal> = inputs.iter().collect();

        let reference = InterpExecutable::from_text_threads(&text, 1)
            .unwrap()
            .run_treewalk(&refs)
            .unwrap_or_else(|e| panic!("{}: tree-walk failed: {e:#}", spec.name));

        let exe =
            InterpExecutable::from_text_sched(&text, THREADS, FuseMode::Full, true).unwrap();
        if exe.sched_enabled() {
            scheduled_wide += 1;
        }
        for run in 0..RUNS {
            let got = exe
                .run(&refs)
                .unwrap_or_else(|e| panic!("{} run {run}: scheduled run failed: {e:#}", spec.name));
            assert_eq!(got.len(), reference.len(), "{}: output arity", spec.name);
            for (o, (g, w)) in got.iter().zip(&reference).enumerate() {
                assert_bitwise(g, w, &format!("{} run {run} output {o}", spec.name));
            }
        }
    }
    // The training/eval graphs are wide; if none of the committed
    // artifacts engaged the scheduler this "stress" test silently became
    // a serial no-op — fail loudly instead.
    assert!(
        scheduled_wide >= 4,
        "only {scheduled_wide} artifacts engaged the step scheduler; \
         stress coverage collapsed"
    );
}
