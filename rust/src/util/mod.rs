//! Shared substrates: deterministic RNG, running statistics, timers,
//! human formatting, a minimal JSON parser, and a scoped thread pool.
//!
//! This environment is offline, so the usual crates (`rand`, `serde_json`,
//! `rayon`) are re-implemented here at the scale this project needs; each
//! submodule carries its own unit tests.

pub mod fmt;
pub mod json;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod timer;
