//! Synthetic multilingual corpus generator.
//!
//! Substitute for the Wikipedia dumps Polyglot trained on (DESIGN.md §2):
//! per language we synthesize a distinct lexicon (language-flavored
//! syllable inventories), draw unigrams from a Zipf–Mandelbrot law, and
//! overlay first-order Markov structure — each word prefers a small set of
//! successors — so that windows are *predictable* and the ranking loss has
//! signal to descend. Sentence lengths are geometric-ish around a mean.
//!
//! Generation is sharded across a thread pool: each language is an
//! independent seeded stream, so output is deterministic for a given spec
//! regardless of thread scheduling.

use crate::util::rng::Rng;
use crate::util::threadpool::par_map;

use super::zipf::Zipf;

/// Per-language syllable inventories — enough variety that vocabularies of
/// different "languages" don't collide and look plausibly distinct.
const ONSETS: [&[&str]; 5] = [
    &["b", "d", "g", "k", "l", "m", "n", "p", "r", "s", "t", "v"],
    &["ch", "sh", "k", "t", "n", "m", "h", "r", "s", "w", "y"],
    &["br", "tr", "kr", "pl", "st", "f", "g", "d", "l", "z"],
    &["q", "x", "zh", "j", "g", "b", "d", "t", "k", "n"],
    &["th", "ph", "v", "s", "m", "n", "l", "r", "d", "h"],
];
const NUCLEI: [&[&str]; 5] = [
    &["a", "e", "i", "o", "u"],
    &["a", "i", "u", "ai", "ei"],
    &["a", "e", "o", "au", "ie"],
    &["a", "o", "u", "uo", "ia"],
    &["e", "i", "y", "ea", "oa"],
];
const CODAS: [&[&str]; 5] = [
    &["", "", "n", "s", "l", "r"],
    &["", "", "", "n", "ku", "ra"],
    &["", "k", "t", "sh", "m", ""],
    &["", "ng", "n", "", "r", ""],
    &["", "s", "th", "m", "", "l"],
];

#[derive(Clone, Debug)]
pub struct CorpusSpec {
    pub languages: usize,
    pub tokens_per_language: usize,
    /// Lexicon types per language (before Zipf truncation effects).
    pub lexicon: usize,
    /// Mean sentence length in tokens.
    pub mean_sentence: usize,
    /// Probability of following the Markov successor preference instead of
    /// an independent Zipf draw — the "learnability" dial.
    pub bigram_alpha: f64,
    /// Successor-set size per word.
    pub successors: usize,
    pub seed: u64,
    pub threads: usize,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        Self {
            languages: 3,
            tokens_per_language: 200_000,
            lexicon: 8000,
            mean_sentence: 18,
            bigram_alpha: 0.65,
            successors: 4,
            seed: 0xC0FFEE,
            threads: 4,
        }
    }
}

/// A generated corpus: sentences of string tokens, per language.
#[derive(Clone, Debug)]
pub struct SyntheticCorpus {
    pub spec_languages: usize,
    pub sentences: Vec<Vec<String>>,
}

impl SyntheticCorpus {
    pub fn total_tokens(&self) -> usize {
        self.sentences.iter().map(|s| s.len()).sum()
    }
}

/// Deterministic lexicon for language `lang`: `lexicon` unique word forms.
pub fn lexicon(lang: usize, size: usize, seed: u64) -> Vec<String> {
    let style = lang % ONSETS.len();
    let mut rng = Rng::new(seed ^ (lang as u64).wrapping_mul(0x517c_c1b7_2722_0a95));
    let mut words = Vec::with_capacity(size);
    let mut seen = std::collections::HashSet::new();
    while words.len() < size {
        let syllables = 1 + rng.below_usize(3);
        let mut w = String::new();
        for _ in 0..=syllables {
            w.push_str(ONSETS[style][rng.below_usize(ONSETS[style].len())]);
            w.push_str(NUCLEI[style][rng.below_usize(NUCLEI[style].len())]);
            w.push_str(CODAS[style][rng.below_usize(CODAS[style].len())]);
        }
        if !seen.insert(w.clone()) {
            // collision: make unique deterministically
            w.push_str(&format!("{}", words.len()));
            seen.insert(w.clone());
        }
        words.push(w);
    }
    words
}

/// Generate one language's sentences.
fn generate_language(lang: usize, spec: &CorpusSpec) -> Vec<Vec<String>> {
    let words = lexicon(lang, spec.lexicon, spec.seed);
    let zipf = Zipf::classic(spec.lexicon);
    let mut rng = Rng::new(spec.seed ^ 0xABCD_0000 ^ lang as u64);

    // Markov successor table: rank -> preferred successor ranks. Derived
    // from a per-language seeded stream so it is stable across runs.
    let mut succ_rng = Rng::new(spec.seed ^ 0xBEEF_0000 ^ lang as u64);
    let succ: Vec<Vec<usize>> = (0..spec.lexicon)
        .map(|_| (0..spec.successors).map(|_| zipf.sample(&mut succ_rng)).collect())
        .collect();

    let mut sentences = Vec::new();
    let mut emitted = 0usize;
    while emitted < spec.tokens_per_language {
        let len = 3 + geometric(&mut rng, spec.mean_sentence.saturating_sub(3).max(1));
        let mut sent = Vec::with_capacity(len);
        let mut prev = zipf.sample(&mut rng);
        sent.push(words[prev].clone());
        for _ in 1..len {
            let next = if rng.f64() < spec.bigram_alpha {
                succ[prev][rng.below_usize(spec.successors)]
            } else {
                zipf.sample(&mut rng)
            };
            sent.push(words[next].clone());
            prev = next;
        }
        emitted += sent.len();
        sentences.push(sent);
    }
    sentences
}

fn geometric(rng: &mut Rng, mean: usize) -> usize {
    // geometric with given mean, capped for sanity
    let p = 1.0 / mean as f64;
    let mut n = 0;
    while rng.f64() > p && n < mean * 8 {
        n += 1;
    }
    n
}

/// Generate the whole corpus (languages in parallel, order deterministic).
pub fn generate(spec: &CorpusSpec) -> SyntheticCorpus {
    let spec_arc = spec.clone();
    let per_lang =
        par_map(spec.languages, spec.threads, move |lang| generate_language(lang, &spec_arc));
    let mut sentences = Vec::new();
    for mut s in per_lang {
        sentences.append(&mut s);
    }
    SyntheticCorpus { spec_languages: spec.languages, sentences }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> CorpusSpec {
        CorpusSpec {
            languages: 2,
            tokens_per_language: 5_000,
            lexicon: 500,
            threads: 2,
            ..CorpusSpec::default()
        }
    }

    #[test]
    fn deterministic_across_runs_and_threads() {
        let a = generate(&small_spec());
        let b = generate(&CorpusSpec { threads: 1, ..small_spec() });
        assert_eq!(a.sentences, b.sentences);
    }

    #[test]
    fn token_budget_met() {
        let c = generate(&small_spec());
        assert!(c.total_tokens() >= 10_000);
        assert!(c.total_tokens() < 13_000, "overshoot: {}", c.total_tokens());
    }

    #[test]
    fn lexicons_unique_and_language_distinct() {
        let a = lexicon(0, 300, 7);
        let b = lexicon(1, 300, 7);
        let set_a: std::collections::HashSet<_> = a.iter().collect();
        assert_eq!(set_a.len(), 300, "duplicates in lexicon");
        let overlap = b.iter().filter(|w| set_a.contains(w)).count();
        assert!(overlap < 30, "languages too similar: {overlap}");
    }

    #[test]
    fn zipfian_head_dominates() {
        let c = generate(&small_spec());
        let mut freq = std::collections::HashMap::new();
        for s in &c.sentences {
            for w in s {
                *freq.entry(w.clone()).or_insert(0usize) += 1;
            }
        }
        let total: usize = freq.values().sum();
        let mut counts: Vec<usize> = freq.values().copied().collect();
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let head: usize = counts.iter().take(50).sum();
        assert!(
            head as f64 / total as f64 > 0.3,
            "head mass {:.3}",
            head as f64 / total as f64
        );
    }

    #[test]
    fn bigram_structure_present() {
        // With alpha=0.65 the corpus must have far fewer distinct bigrams
        // than an independent draw would produce.
        let spec = CorpusSpec { bigram_alpha: 0.9, ..small_spec() };
        let c = generate(&spec);
        let mut bigrams = std::collections::HashSet::new();
        let mut n = 0usize;
        for s in &c.sentences {
            for w in s.windows(2) {
                bigrams.insert((w[0].clone(), w[1].clone()));
                n += 1;
            }
        }
        let ratio = bigrams.len() as f64 / n as f64;
        assert!(ratio < 0.55, "bigram diversity too high: {ratio:.3}");
    }

    #[test]
    fn sentences_nonempty_and_bounded() {
        let c = generate(&small_spec());
        for s in &c.sentences {
            assert!(s.len() >= 3);
            assert!(s.len() < 200);
        }
    }
}
