//! Persistent parked worker pool (rayon/tokio are unavailable offline).
//!
//! Used by the corpus generator (per-shard synthesis), the data pipeline's
//! producer threads, the TCP server's connection handlers, the gradient
//! subsystem's sharded scatter, and — since the plan-level scheduler — the
//! HLO interpreter, where *step-level* parallelism (independent plan steps)
//! and *kernel-internal* row blocking share this one pool.
//!
//! Design: one shared FIFO injector queue under a mutex, workers park on a
//! condvar when it drains. Joins **help**: a thread waiting for its own
//! scoped tasks pops and runs queued jobs (its own or anyone else's)
//! instead of blocking. That is the permit discipline that lets nested
//! fan-outs share the pool without oversubscribing — a worker executing a
//! plan step whose kernel fans out again never spawns a thread and never
//! deadlocks, because every waiter drains the queue while it waits and
//! every queued task eventually runs on one of the fixed `threads + 1`
//! participating threads (workers + the joining caller).

// Crate-root carve-out (`#![deny(unsafe_code)]` in lib.rs): the scoped
// lifetime erasure and the pool back-pointer below are the crate's
// rayon-replacement primitives; each unsafe block documents its SAFETY
// argument.
#![allow(unsafe_code)]

use std::collections::VecDeque;
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A scoped pool task panicked. The payload is captured so callers can
/// degrade — answer one request batch with an error, abort one training
/// step — instead of the process dying on an assert. Converts into
/// `anyhow::Error` (it is a `std::error::Error`), so kernel and trainer
/// call sites just `?` it.
#[derive(Debug)]
pub struct PoolPanic {
    payload: String,
}

impl PoolPanic {
    pub fn payload(&self) -> &str {
        &self.payload
    }
}

impl std::fmt::Display for PoolPanic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "pool task panicked: {}", self.payload)
    }
}

impl std::error::Error for PoolPanic {}

/// Extract a human-readable payload from `catch_unwind`'s error value
/// (`panic!("...")` yields `&str` or `String`; anything else is opaque).
fn panic_payload(e: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// The `pool.task.panic` failpoint: injected at scoped-task entry so the
/// chaos suite can prove a panic anywhere in a fan-out surfaces as a
/// contained `Err`, not a process abort.
fn maybe_inject_task_panic() {
    if crate::util::failpoint::fire("pool.task.panic") {
        panic!("failpoint pool.task.panic");
    }
}

struct PoolState {
    queue: VecDeque<Job>,
    shutdown: bool,
}

struct Inner {
    state: Mutex<PoolState>,
    /// Workers park here when the queue is empty.
    work_cv: Condvar,
}

/// A fixed pool of parked worker threads consuming a shared queue.
/// `&ThreadPool` is `Sync`: kernels and the plan scheduler share one
/// instance across worker threads.
pub struct ThreadPool {
    inner: Arc<Inner>,
    workers: Vec<thread::JoinHandle<()>>,
}

/// Run a job with worker-grade panic isolation: a panicking job must not
/// kill its thread (or a helping caller), or jobs queued behind it would
/// never run and scoped joins would wait forever.
fn run_isolated(job: Job) {
    if std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)).is_err() {
        eprintln!("[threadpool] job panicked; worker continues");
    }
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let inner = Arc::new(Inner {
            state: Mutex::new(PoolState { queue: VecDeque::new(), shutdown: false }),
            work_cv: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|i| {
                let inner = Arc::clone(&inner);
                thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let mut st = inner.state.lock().unwrap();
                            loop {
                                if let Some(j) = st.queue.pop_front() {
                                    break Some(j);
                                }
                                if st.shutdown {
                                    break None;
                                }
                                st = inner.work_cv.wait(st).unwrap();
                            }
                        };
                        match job {
                            Some(j) => run_isolated(j),
                            None => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { inner, workers }
    }

    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.push(Box::new(job));
    }

    fn push(&self, job: Job) {
        let mut st = self.inner.state.lock().unwrap();
        assert!(!st.shutdown, "pool closed");
        st.queue.push_back(job);
        drop(st);
        self.inner.work_cv.notify_one();
    }

    /// Steal one queued job, if any — the helping-join primitive.
    fn try_pop(&self) -> Option<Job> {
        self.inner.state.lock().unwrap().queue.pop_front()
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Run `f(0) … f(n-1)` on the pool and block until every task has
    /// finished — a *scoped* fan-out: `f` may borrow from the caller's
    /// stack, unlike `execute`, because this call does not return while
    /// any task is live. This is the kernel/grad dispatch primitive: it
    /// avoids the per-call `Arc`/`to_vec` copies `par_map` pays for
    /// `'static` closures. The caller does not idle at the join: it pops
    /// and runs queued jobs (its own tasks, or anyone else's) until its
    /// scope drains — which is what makes *nested* scope_run calls from
    /// pool workers safe to issue against the same pool.
    ///
    /// A panicking task does not kill anything: the scope still drains
    /// every task, and the first panic's payload comes back as
    /// `Err(PoolPanic)` — fault containment for the batcher (one bad
    /// batch answers ERR, the server keeps serving) and the trainer
    /// (one bad step surfaces as a step error).
    pub fn scope_run(&self, n: usize, f: &(dyn Fn(usize) + Sync)) -> Result<(), PoolPanic> {
        if n == 0 {
            return Ok(());
        }
        if n == 1 {
            // Serial chain: zero dispatch overhead, same containment.
            return std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                maybe_inject_task_panic();
                f(0);
            }))
            .map_err(|e| PoolPanic { payload: panic_payload(e) });
        }
        // SAFETY: the borrowed closure is lifetime-erased so it can ride
        // the pool's 'static job queue. Soundness: every enqueued task
        // bumps `done` after `f` returns or unwinds, and this frame does
        // not return until `done == n`, so no task can touch `f` after
        // the frame is gone. Jobs are never dropped un-run while a scope
        // is live (Drop needs `&mut self`, scoped calls hold `&self`).
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
        };
        let scope = Arc::new(ScopeSync::default());
        for i in 0..n {
            let scope = Arc::clone(&scope);
            self.push(Box::new(move || {
                let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    maybe_inject_task_panic();
                    f_static(i)
                }));
                if let Err(e) = caught {
                    scope.record_panic(panic_payload(e));
                }
                scope.complete();
            }));
        }
        self.help_until(&scope, n);
        scope.into_result()
    }

    /// Dynamic scoped task set: seed tasks may [`Spawner::spawn`] more
    /// tasks; returns when every spawned task has completed. Same borrow
    /// contract and helping join as [`ThreadPool::scope_run`] — this is
    /// the plan scheduler's driver: ready steps are seeded, each finished
    /// step spawns the successors it released.
    pub fn scope_dyn(
        &self,
        seed: &[usize],
        f: &(dyn Fn(usize, &Spawner) + Sync),
    ) -> Result<(), PoolPanic> {
        if seed.is_empty() {
            return Ok(());
        }
        // SAFETY: as in scope_run — no task outlives this frame because
        // the helping loop below only returns at `done == spawned`, and
        // both counters are owned by the Arc'd scope.
        let f_static: &'static (dyn Fn(usize, &Spawner) + Sync) = unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize, &Spawner) + Sync),
                &'static (dyn Fn(usize, &Spawner) + Sync),
            >(f)
        };
        let scope = Arc::new(DynScope {
            sync: ScopeSync::default(),
            spawned: Mutex::new(0),
        });
        let spawner = Spawner { pool: self, scope: &scope, f: f_static };
        for &t in seed {
            spawner.spawn(t);
        }
        // Help until everything spawned (including tasks spawned by
        // tasks) has completed. `spawned` only grows from live tasks, and
        // a task increments it *before* its own completion is counted, so
        // observing done == spawned with no live tasks is a fixed point.
        loop {
            if let Some(job) = self.try_pop() {
                run_isolated(job);
                continue;
            }
            let done = self.scope_wait(&scope.sync, || *scope.spawned.lock().unwrap());
            if done {
                break;
            }
        }
        scope.sync.into_result()
    }

    /// Help-run queued jobs until `scope.done == n`.
    fn help_until(&self, scope: &ScopeSync, n: usize) {
        loop {
            if let Some(job) = self.try_pop() {
                run_isolated(job);
                continue;
            }
            if self.scope_wait(scope, || n) {
                break;
            }
        }
    }

    /// One park-or-finish round: returns true when the scope is drained,
    /// otherwise sleeps until a completion arrives (then returns false so
    /// the caller re-checks the queue and helps again).
    fn scope_wait(&self, scope: &ScopeSync, target: impl Fn() -> usize) -> bool {
        let mut done = scope.done.lock().unwrap();
        if *done >= target() {
            return true;
        }
        done = scope.cv.wait(done).unwrap();
        *done >= target()
    }
}

/// Join-side state of a scoped fan-out: completion count + wakeup +
/// the first panic's payload (first-panic-wins, like the interpreter's
/// first-error-wins abort).
#[derive(Default)]
struct ScopeSync {
    done: Mutex<usize>,
    cv: Condvar,
    panic: Mutex<Option<String>>,
}

impl ScopeSync {
    fn complete(&self) {
        let mut d = self.done.lock().unwrap();
        *d += 1;
        drop(d);
        // Every completion wakes the joiner so it can resume helping —
        // a completed task may have spawned work the joiner should run.
        self.cv.notify_all();
    }

    fn record_panic(&self, payload: String) {
        let mut slot = self.panic.lock().unwrap();
        if slot.is_none() {
            *slot = Some(payload);
        }
    }

    fn into_result(&self) -> Result<(), PoolPanic> {
        match self.panic.lock().unwrap().take() {
            Some(payload) => Err(PoolPanic { payload }),
            None => Ok(()),
        }
    }
}

struct DynScope {
    sync: ScopeSync,
    /// Total tasks ever spawned into this scope (target for `done`).
    spawned: Mutex<usize>,
}

/// Capability to add tasks to a live [`ThreadPool::scope_dyn`] scope.
pub struct Spawner<'a> {
    pool: &'a ThreadPool,
    scope: &'a Arc<DynScope>,
    f: &'static (dyn Fn(usize, &Spawner) + Sync),
}

/// SAFETY: `&ThreadPool` is only dereferenced while the owning scope is
/// live (scope_dyn does not return before every task completes).
struct PoolPtr(*const ThreadPool);
unsafe impl Send for PoolPtr {}

impl Spawner<'_> {
    /// Enqueue `task` into the scope. May be called from inside any task
    /// of the same scope (that is the point).
    pub fn spawn(&self, task: usize) {
        *self.scope.spawned.lock().unwrap() += 1;
        let scope = Arc::clone(self.scope);
        let f = self.f;
        let pp = PoolPtr(self.pool as *const ThreadPool);
        self.pool.push(Box::new(move || {
            let pool = unsafe { &*pp.0 };
            let spawner = Spawner { pool, scope: &scope, f };
            let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                maybe_inject_task_panic();
                f(task, &spawner)
            }));
            if let Err(e) = caught {
                scope.sync.record_panic(panic_payload(e));
            }
            scope.sync.complete();
        }));
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
        }
        self.inner.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

static SHARED: OnceLock<ThreadPool> = OnceLock::new();

/// The one process-wide persistent pool. Every compute fan-out in the
/// crate — interpreter step scheduling, kernel row blocking, the sharded
/// scatter, and the scoring server's batch executions — queues here, so
/// nesting any of them inside any other neither oversubscribes the
/// machine nor deadlocks (helping joins drain the shared queue).
///
/// Sized `resolve_threads(0) - 1` workers (min 1): scoped joins help run
/// queued work, so the dispatching thread is the remaining runner and
/// total concurrency stays at the resolved thread budget. Callers that
/// want *less* parallelism than the machine allows express it through
/// their chunk counts (`Par::threads`, `ShardPlan` shards), never by
/// sizing a private pool — results are bitwise-independent of worker
/// count by construction.
pub fn shared() -> &'static ThreadPool {
    SHARED.get_or_init(|| {
        let budget = crate::grad::resolve_threads(0);
        ThreadPool::new(budget.saturating_sub(1).max(1))
    })
}

/// Run `f` over each index in `0..n` on up to `threads` threads, collecting
/// results in order — a scoped parallel map.
pub fn par_map<T: Send + 'static>(
    n: usize,
    threads: usize,
    f: impl Fn(usize) -> T + Send + Sync + 'static,
) -> Vec<T> {
    let f = Arc::new(f);
    let (tx, rx) = mpsc::channel();
    let pool = ThreadPool::new(threads.max(1).min(n.max(1)));
    for i in 0..n {
        let tx = tx.clone();
        let f = Arc::clone(&f);
        pool.execute(move || {
            let v = f(i);
            let _ = tx.send((i, v));
        });
    }
    drop(tx);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (i, v) in rx {
        out[i] = Some(v);
    }
    out.into_iter().map(|v| v.expect("worker panicked")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(4);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop drains the queue, then joins
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn par_map_preserves_order() {
        let out = par_map(50, 8, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_zero_items() {
        let out: Vec<usize> = par_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn scope_run_borrows_stack_data() {
        let pool = ThreadPool::new(4);
        let input: Vec<u64> = (0..64).collect();
        let out: Vec<Mutex<u64>> = (0..64).map(|_| Mutex::new(0)).collect();
        pool.scope_run(64, &|i| {
            *out[i].lock().unwrap() = input[i] * 3;
        })
        .unwrap();
        for (i, m) in out.iter().enumerate() {
            assert_eq!(*m.lock().unwrap(), i as u64 * 3);
        }
    }

    #[test]
    fn scope_run_returns_err_with_payload_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let err = pool
            .scope_run(8, &|i| {
                assert!(i != 3, "boom");
            })
            .unwrap_err();
        assert!(err.payload().contains("boom"), "payload captured: {err}");
        assert!(err.to_string().contains("pool task panicked"));
        // the pool keeps working afterwards (workers are panic-isolated)
        let counter = AtomicUsize::new(0);
        pool.scope_run(4, &|_| {
            counter.fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn scope_run_single_task_panic_is_contained_too() {
        // n == 1 takes the inline fast path; containment must be uniform.
        let pool = ThreadPool::new(2);
        let err = pool.scope_run(1, &|_| panic!("solo")).unwrap_err();
        assert!(err.payload().contains("solo"));
        pool.scope_run(1, &|_| {}).unwrap();
    }

    #[test]
    fn scope_run_zero_and_reuse() {
        let pool = ThreadPool::new(2);
        pool.scope_run(0, &|_| panic!("must not run")).unwrap();
        let counter = AtomicUsize::new(0);
        for _ in 0..3 {
            pool.scope_run(10, &|_| {
                counter.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 30);
    }

    #[test]
    fn nested_scope_run_shares_the_pool_without_deadlock() {
        // The scheduler's shape: outer tasks (plan steps) each fan out an
        // inner scope (kernel row blocks) against the SAME pool. With a
        // blocking join this deadlocks as soon as every worker holds an
        // outer task; with helping joins it must complete — on a pool
        // deliberately smaller than the outer width.
        let pool = ThreadPool::new(2);
        let counter = AtomicUsize::new(0);
        pool.scope_run(8, &|_| {
            pool.scope_run(4, &|_| {
                counter.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        })
        .unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn scope_dyn_runs_spawned_chains() {
        // Seed one task per chain; each task spawns its successor until
        // the chain reaches the target length: 4 chains x depth 25.
        let pool = ThreadPool::new(3);
        let counter = AtomicUsize::new(0);
        pool.scope_dyn(&[0, 100, 200, 300], &|task, sp| {
            counter.fetch_add(1, Ordering::SeqCst);
            if task % 100 < 24 {
                sp.spawn(task + 1);
            }
        })
        .unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_dyn_returns_err_with_payload() {
        let pool = ThreadPool::new(2);
        let err = pool
            .scope_dyn(&[0, 1, 2, 3], &|task, _| {
                assert!(task != 2, "boom");
            })
            .unwrap_err();
        assert!(err.payload().contains("boom"), "payload captured: {err}");
        let counter = AtomicUsize::new(0);
        pool.scope_dyn(&[0], &|_, _| {
            counter.fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn scope_dyn_empty_seed_is_a_noop() {
        let pool = ThreadPool::new(2);
        pool.scope_dyn(&[], &|_, _| panic!("must not run")).unwrap();
    }

    #[test]
    fn pool_task_panic_failpoint_surfaces_as_err_then_recovers() {
        let pool = ThreadPool::new(2);
        {
            let _fp = crate::util::failpoint::scoped("pool.task.panic=once");
            let err = pool.scope_run(4, &|_| {}).unwrap_err();
            assert!(err.payload().contains("pool.task.panic"));
            // `once` consumed: the very next fan-out is clean.
            pool.scope_run(4, &|_| {}).unwrap();
        }
        pool.scope_run(4, &|_| {}).unwrap();
    }

    #[test]
    fn pool_is_sync() {
        fn assert_sync<T: Sync>() {}
        assert_sync::<ThreadPool>();
    }

    #[test]
    fn shared_pool_survives_server_fanout_nested_in_scatter_scope() {
        // Pool-unification contract: the scoring server's batch
        // executions and the sharded scatter share ONE pool. The worst
        // nesting — request fan-outs issued from *inside* a live
        // scatter scope, each fanning out kernel row blocks of its own —
        // must complete (helping joins) without spawning any thread
        // beyond the fixed worker set.
        let pool = shared();
        let workers_before = pool.threads();
        let counter = AtomicUsize::new(0);
        // Outer scope: a sharded scatter's per-shard tasks.
        pool.scope_run(8, &|_| {
            // Nested: a server batch execution dispatched onto the same
            // pool from within the scatter scope...
            pool.scope_run(4, &|_| {
                // ...whose kernels row-block on the pool again.
                pool.scope_run(2, &|_| {
                    counter.fetch_add(1, Ordering::SeqCst);
                })
                .unwrap();
            })
            .unwrap();
        })
        .unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 8 * 4 * 2);
        assert_eq!(pool.threads(), workers_before, "no oversubscription");
        // Fire-and-forget dispatches (the batcher's execution path)
        // interleave with scoped work on the same queue.
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..16 {
            let d = Arc::clone(&done);
            pool.execute(move || {
                d.fetch_add(1, Ordering::SeqCst);
            });
        }
        while done.load(Ordering::SeqCst) < 16 {
            std::thread::yield_now();
        }
    }
}
