//! Command-line parsing (clap is unavailable offline).
//!
//! Grammar: `polyglot <subcommand> [--flag value] [--switch] [positional…]`.
//! Flags may be declared as required/optional with defaults; `--set k=v`
//! may repeat and accumulates into config overrides. `--help` renders an
//! auto-generated usage page.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// None = boolean switch; Some(default) = value flag (empty string ⇒
    /// required).
    pub default: Option<&'static str>,
}

#[derive(Clone, Debug)]
pub struct CommandSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub flags: Vec<FlagSpec>,
}

/// Parsed invocation.
#[derive(Clone, Debug)]
pub struct Invocation {
    pub command: String,
    pub values: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub sets: Vec<(String, String)>,
    pub positional: Vec<String>,
}

impl Invocation {
    pub fn get(&self, flag: &str) -> Option<&str> {
        self.values.get(flag).map(|s| s.as_str())
    }

    pub fn get_usize(&self, flag: &str) -> Result<usize> {
        let v = self.values.get(flag).ok_or_else(|| anyhow::anyhow!("missing --{flag}"))?;
        Ok(v.parse()?)
    }

    pub fn get_f64(&self, flag: &str) -> Result<f64> {
        let v = self.values.get(flag).ok_or_else(|| anyhow::anyhow!("missing --{flag}"))?;
        Ok(v.parse()?)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

pub struct Cli {
    pub program: &'static str,
    pub about: &'static str,
    pub commands: Vec<CommandSpec>,
}

impl Cli {
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [flags]\n\nCOMMANDS:\n",
            self.program, self.about, self.program);
        for c in &self.commands {
            s.push_str(&format!("  {:<14} {}\n", c.name, c.about));
        }
        s.push_str("\nRun `<command> --help` for that command's flags.\n");
        s
    }

    pub fn command_usage(&self, cmd: &CommandSpec) -> String {
        let mut s = format!("{} {} — {}\n\nFLAGS:\n", self.program, cmd.name, cmd.about);
        for f in &cmd.flags {
            let kind = match f.default {
                None => "switch".to_string(),
                Some("") => "required".to_string(),
                Some(d) => format!("default: {d}"),
            };
            s.push_str(&format!("  --{:<22} {} [{kind}]\n", f.name, f.help));
        }
        s.push_str("  --set <section.key=v>   override a config value (repeatable)\n");
        s.push_str("  --config <path>          config file (TOML subset)\n");
        s
    }

    /// Parse argv (excluding argv[0]). Returns Err(msg) where msg is the
    /// help text for `--help` flows (caller prints and exits 0 on
    /// `HelpRequested`).
    pub fn parse(&self, args: &[String]) -> Result<Invocation, CliError> {
        let Some(cmd_name) = args.first() else {
            return Err(CliError::HelpRequested(self.usage()));
        };
        if cmd_name == "--help" || cmd_name == "-h" || cmd_name == "help" {
            return Err(CliError::HelpRequested(self.usage()));
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| CliError::Invalid(format!(
                "unknown command {cmd_name:?}\n\n{}", self.usage())))?;

        let mut inv = Invocation {
            command: cmd.name.to_string(),
            values: BTreeMap::new(),
            switches: Vec::new(),
            sets: Vec::new(),
            positional: Vec::new(),
        };
        // seed defaults
        for f in &cmd.flags {
            if let Some(d) = f.default {
                if !d.is_empty() {
                    inv.values.insert(f.name.to_string(), d.to_string());
                }
            }
        }

        let mut i = 1;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(CliError::HelpRequested(self.command_usage(cmd)));
            }
            if let Some(name) = a.strip_prefix("--") {
                if name == "set" {
                    i += 1;
                    let kv = args.get(i).ok_or_else(|| {
                        CliError::Invalid("--set requires section.key=value".into())
                    })?;
                    let (k, v) = kv.split_once('=').ok_or_else(|| {
                        CliError::Invalid(format!("--set {kv:?}: expected key=value"))
                    })?;
                    inv.sets.push((k.to_string(), v.to_string()));
                } else if name == "config" {
                    i += 1;
                    let p = args.get(i).ok_or_else(|| {
                        CliError::Invalid("--config requires a path".into())
                    })?;
                    inv.values.insert("config".into(), p.clone());
                } else {
                    let spec = cmd.flags.iter().find(|f| f.name == name).ok_or_else(|| {
                        CliError::Invalid(format!(
                            "unknown flag --{name} for {}\n\n{}",
                            cmd.name,
                            self.command_usage(cmd)
                        ))
                    })?;
                    match spec.default {
                        None => inv.switches.push(name.to_string()),
                        Some(_) => {
                            i += 1;
                            let v = args.get(i).ok_or_else(|| {
                                CliError::Invalid(format!("--{name} requires a value"))
                            })?;
                            inv.values.insert(name.to_string(), v.clone());
                        }
                    }
                }
            } else {
                inv.positional.push(a.clone());
            }
            i += 1;
        }

        // required flags
        for f in &cmd.flags {
            if f.default == Some("") && !inv.values.contains_key(f.name) {
                return Err(CliError::Invalid(format!(
                    "missing required flag --{} for {}", f.name, cmd.name)));
            }
        }
        Ok(inv)
    }
}

#[derive(Debug)]
pub enum CliError {
    HelpRequested(String),
    Invalid(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::HelpRequested(s) | CliError::Invalid(s) => write!(f, "{s}"),
        }
    }
}

impl std::error::Error for CliError {}

pub fn bail_unknown(cmd: &str) -> Result<()> {
    bail!("unhandled command {cmd}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli {
            program: "polyglot",
            about: "test",
            commands: vec![CommandSpec {
                name: "train",
                about: "train a model",
                flags: vec![
                    FlagSpec { name: "steps", help: "steps", default: Some("100") },
                    FlagSpec { name: "out", help: "path", default: Some("") },
                    FlagSpec { name: "verbose", help: "chatty", default: None },
                ],
            }],
        }
    }

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_defaults_switches() {
        let inv = cli().parse(&argv("train --out /tmp/x --verbose --set training.lr=0.1")).unwrap();
        assert_eq!(inv.get("steps"), Some("100"));
        assert_eq!(inv.get("out"), Some("/tmp/x"));
        assert!(inv.has("verbose"));
        assert_eq!(inv.sets, vec![("training.lr".into(), "0.1".into())]);
    }

    #[test]
    fn required_flag_enforced() {
        assert!(matches!(cli().parse(&argv("train")), Err(CliError::Invalid(_))));
    }

    #[test]
    fn help_flows() {
        assert!(matches!(cli().parse(&argv("--help")), Err(CliError::HelpRequested(_))));
        assert!(matches!(
            cli().parse(&argv("train --help")),
            Err(CliError::HelpRequested(_))
        ));
        assert!(matches!(cli().parse(&[]), Err(CliError::HelpRequested(_))));
    }

    #[test]
    fn unknown_command_and_flag_rejected() {
        assert!(matches!(cli().parse(&argv("serve")), Err(CliError::Invalid(_))));
        assert!(matches!(
            cli().parse(&argv("train --out x --bogus")),
            Err(CliError::Invalid(_))
        ));
    }

    #[test]
    fn positional_args_collected() {
        let inv = cli().parse(&argv("train --out x a b")).unwrap();
        assert_eq!(inv.positional, vec!["a", "b"]);
    }
}
