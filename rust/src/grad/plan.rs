//! Zipf-aware vocabulary shard plan for scatter-add.
//!
//! A plan partitions the *update stream* (positions `0..idx.len()`) into
//! per-shard work lists such that all updates targeting a given
//! destination row land in the same shard, in stream order. Two
//! consequences:
//!
//! 1. Shards own disjoint destination rows — threads never race, no
//!    atomics (the conflict-avoidance the paper's CUDA kernel bought with
//!    `atomicAdd`).
//! 2. Per-row update order matches the serial loop, so the sharded result
//!    is bitwise identical to `baselines::scatter::scatter_add_serial`.
//!
//! Under a Zipf-skewed stream a plain `hash(row) % shards` split is
//! pathological: the head word's updates all hash to one shard and that
//! thread serializes most of the batch. The plan therefore pins each
//! sufficiently-hot row to one of a reserved set of **dedicated shards**
//! (up to half the shard count), and hashes only the long tail across the
//! remaining shards.

use std::collections::HashMap;

/// A partition of scatter updates into owner shards.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// Per-shard ascending lists of update positions into the idx stream.
    pub shards: Vec<Vec<u32>>,
    /// Rows that received dedicated-shard treatment this batch (the Zipf
    /// head), most frequent first. Diagnostics and tests.
    pub hot: Vec<i32>,
}

impl ShardPlan {
    /// Build a plan for `idx` over `shards` owner shards, pinning up to
    /// `hot_budget` frequent rows to dedicated shards.
    pub fn build(idx: &[i32], shards: usize, hot_budget: usize) -> ShardPlan {
        let n = shards.max(1);
        if n == 1 {
            return ShardPlan { shards: vec![(0..idx.len() as u32).collect()], hot: Vec::new() };
        }

        // Histogram of touched rows (sparse: touched rows <= idx.len()).
        let mut counts: HashMap<i32, u32> = HashMap::new();
        for &i in idx {
            *counts.entry(i).or_insert(0) += 1;
        }

        // A row is hot once hashing it with the tail would meaningfully
        // unbalance a shard: count >= a quarter of one shard's fair share
        // of the stream. (The Zipf-Mandelbrot head word carries ~5-7% of
        // a natural stream — well above this, far below a full share.)
        let threshold = (idx.len() / (4 * n)).max(4) as u32;
        let mut hot: Vec<(i32, u32)> = counts
            .iter()
            .filter(|&(_, &c)| c >= threshold)
            .map(|(&i, &c)| (i, c))
            .collect();
        // Deterministic: by count descending, row id as tie-break.
        hot.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        hot.truncate(hot_budget);

        // Reserve up to half the shards exclusively for the hot head.
        let reserved = hot.len().min(n / 2);
        let hot_shard: HashMap<i32, usize> = if reserved == 0 {
            HashMap::new()
        } else {
            hot.iter().enumerate().map(|(k, &(row, _))| (row, k % reserved)).collect()
        };
        let cold_shards = n - reserved;

        let mut out: Vec<Vec<u32>> = vec![Vec::new(); n];
        for (r, &i) in idx.iter().enumerate() {
            let s = match hot_shard.get(&i) {
                Some(&k) => k,
                None => reserved + (hash_row(i) as usize % cold_shards),
            };
            out[s].push(r as u32);
        }
        ShardPlan { shards: out, hot: hot.into_iter().map(|(i, _)| i).collect() }
    }

    /// Total updates covered by the plan.
    pub fn updates(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }
}

fn hash_row(i: i32) -> u64 {
    // Multiplicative (Fibonacci) hash — cheap and good enough to spread a
    // de-skewed tail.
    ((i as u32 as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)) >> 17
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::zipf::Zipf;
    use crate::util::rng::Rng;

    fn zipf_stream(rows: usize, vocab: usize, seed: u64) -> Vec<i32> {
        let z = Zipf::classic(vocab);
        let mut rng = Rng::new(seed);
        (0..rows).map(|_| z.sample(&mut rng) as i32).collect()
    }

    fn owner_of(plan: &ShardPlan, idx: &[i32]) -> HashMap<i32, usize> {
        let mut owner = HashMap::new();
        for (s, list) in plan.shards.iter().enumerate() {
            for &r in list {
                let row = idx[r as usize];
                let prev = owner.insert(row, s);
                if let Some(p) = prev {
                    assert_eq!(p, s, "row {row} owned by shards {p} and {s}");
                }
            }
        }
        owner
    }

    #[test]
    fn partition_is_exact_and_ordered() {
        let idx = zipf_stream(5000, 300, 1);
        let plan = ShardPlan::build(&idx, 8, 16);
        assert_eq!(plan.shards.len(), 8);
        assert_eq!(plan.updates(), idx.len());
        let mut seen = vec![false; idx.len()];
        for list in &plan.shards {
            for w in list.windows(2) {
                assert!(w[0] < w[1], "shard list not ascending");
            }
            for &r in list {
                assert!(!seen[r as usize], "update {r} assigned twice");
                seen[r as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
        owner_of(&plan, &idx); // asserts single ownership per row
    }

    #[test]
    fn hot_head_gets_dedicated_shards() {
        // Zipf head: rank 0 dominates; it must be pinned, and its shard
        // must hold no hashed tail rows.
        let idx = zipf_stream(8000, 500, 2);
        let plan = ShardPlan::build(&idx, 8, 8);
        assert!(!plan.hot.is_empty(), "zipf stream produced no hot rows");
        let owner = owner_of(&plan, &idx);
        let reserved = plan.hot.len().min(4);
        for row in &plan.hot {
            assert!(owner[row] < reserved, "hot row {row} not in a dedicated shard");
        }
        for (&row, &s) in &owner {
            if !plan.hot.contains(&row) {
                assert!(s >= reserved, "cold row {row} landed in dedicated shard {s}");
            }
        }
    }

    #[test]
    fn balanced_under_skew() {
        // With the head pinned, no shard should carry the majority of a
        // heavily-skewed stream.
        let idx = zipf_stream(20_000, 2000, 3);
        let plan = ShardPlan::build(&idx, 8, 16);
        let max = plan.shards.iter().map(|s| s.len()).max().unwrap();
        assert!(
            max < idx.len() / 2,
            "one shard owns {max} of {} updates",
            idx.len()
        );
    }

    #[test]
    fn single_shard_and_empty_stream() {
        let plan = ShardPlan::build(&[5, 5, 7], 1, 4);
        assert_eq!(plan.shards.len(), 1);
        assert_eq!(plan.shards[0], vec![0, 1, 2]);
        let empty = ShardPlan::build(&[], 4, 4);
        assert_eq!(empty.updates(), 0);
    }

    #[test]
    fn deterministic() {
        let idx = zipf_stream(3000, 400, 9);
        let a = ShardPlan::build(&idx, 6, 8);
        let b = ShardPlan::build(&idx, 6, 8);
        assert_eq!(a.shards, b.shards);
        assert_eq!(a.hot, b.hot);
    }
}
