//! Per-instruction cost model + Theano op-class mapping.
//!
//! FLOP/byte estimates follow XLA's own HloCostAnalysis conventions:
//! elementwise = 1 flop/element, dot = 2·M·N·K, reduce = 1 flop/element of
//! input, data movement ops = bytes moved, control ops = free. The class
//! names are Theano's — Table 1's rows are `GpuAdvancedIncSubtensor1`,
//! `GpuElemwise`, `GpuAlloc` — so the reproduction prints the same labels.

use std::collections::HashMap;

use super::hlo::Instruction;

/// Theano op classes (what Table 1 ranks).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpClass {
    /// scatter / the per-row update loop — `W[I] += Y`.
    AdvancedIncSubtensor,
    /// gather — `W[I]`.
    AdvancedSubtensor,
    /// elementwise arithmetic (add/mul/tanh/max/select/compare...).
    Elemwise,
    /// buffer materialization: broadcast/iota/constant/copy/pad.
    Alloc,
    /// matmul.
    Gemm,
    /// reductions.
    Reduce,
    /// reshape/transpose/slice/concat — layout movement.
    Dimshuffle,
    /// control flow and glue (while/call/tuple/parameter/...).
    Control,
}

impl OpClass {
    /// Theano's name for the class (GPU-prefixed, as in the paper).
    pub fn theano_name(&self) -> &'static str {
        match self {
            OpClass::AdvancedIncSubtensor => "GpuAdvancedIncSubtensor1",
            OpClass::AdvancedSubtensor => "GpuAdvancedSubtensor1",
            OpClass::Elemwise => "GpuElemwise",
            OpClass::Alloc => "GpuAlloc",
            OpClass::Gemm => "GpuGemm",
            OpClass::Reduce => "GpuCAReduce",
            OpClass::Dimshuffle => "GpuDimShuffle",
            OpClass::Control => "(control)",
        }
    }

    pub fn all() -> [OpClass; 8] {
        [
            OpClass::AdvancedIncSubtensor,
            OpClass::AdvancedSubtensor,
            OpClass::Elemwise,
            OpClass::Alloc,
            OpClass::Gemm,
            OpClass::Reduce,
            OpClass::Dimshuffle,
            OpClass::Control,
        ]
    }
}

/// Map an HLO opcode to its Theano class.
pub fn classify(inst: &Instruction) -> OpClass {
    match inst.opcode.as_str() {
        "scatter" | "dynamic-update-slice" => OpClass::AdvancedIncSubtensor,
        "gather" | "dynamic-slice" => OpClass::AdvancedSubtensor,
        "dot" => OpClass::Gemm,
        "reduce" | "reduce-window" => OpClass::Reduce,
        "broadcast" | "iota" | "constant" | "copy" | "pad" => OpClass::Alloc,
        "reshape" | "transpose" | "slice" | "concatenate" | "bitcast"
        | "bitcast-convert" => OpClass::Dimshuffle,
        "add" | "subtract" | "multiply" | "divide" | "maximum" | "minimum"
        | "tanh" | "exponential" | "log" | "negate" | "abs" | "sign" | "power"
        | "select" | "compare" | "and" | "or" | "not" | "xor" | "convert"
        | "clamp" | "floor" | "ceil" | "sqrt" | "rsqrt" | "remainder"
        | "shift-left" | "shift-right-logical" | "shift-right-arithmetic"
        | "is-finite" | "sine" | "cosine" | "atan2" => OpClass::Elemwise,
        _ => OpClass::Control, // parameter, tuple, while, call, custom-call…
    }
}

/// Map an interpreter plan-op label (`Runtime::plan_op_stats`) to its
/// Theano class. Unlike [`classify`], these rows are *measured* — the
/// compiled-plan executor timed each kernel, fused elementwise chains
/// included — so the profiler can report them like the per-row dispatch
/// loop instead of modeling them from HLO counts.
pub fn classify_plan_op(label: &str) -> OpClass {
    match label {
        "scatter" | "dynamic-update-slice" => OpClass::AdvancedIncSubtensor,
        // A fused gather is still gather-shaped work: the epilogue rides
        // the row streaming for free. Same reasoning for fused dot /
        // reduce below.
        "gather" | "fused-gather" | "dynamic-slice" => OpClass::AdvancedSubtensor,
        "dot" | "fused-dot" => OpClass::Gemm,
        "reduce" | "fused-reduce" => OpClass::Reduce,
        "fused" | "elemwise" => OpClass::Elemwise,
        "alloc" => OpClass::Alloc,
        "shape" => OpClass::Dimshuffle,
        _ => OpClass::Control,
    }
}

/// Is this plan-op label one of the interpreter's fused kernels (chain,
/// reduce prologue, dot/gather epilogue)? Used to report the measured
/// fused-kernel time share.
pub fn is_fused_plan_op(label: &str) -> bool {
    matches!(label, "fused" | "fused-reduce" | "fused-dot" | "fused-gather")
}

/// (flops, bytes) estimate for one instruction. `shapes` resolves operand
/// result shapes by name.
pub fn instruction_cost(
    inst: &Instruction,
    shapes: &HashMap<String, Vec<usize>>,
) -> (u64, u64) {
    let out_elems = inst.elements() as u64;
    let out_bytes = inst.bytes() as u64;
    let operand_bytes: u64 = inst
        .operands
        .iter()
        .filter_map(|o| shapes.get(o))
        .map(|s| s.iter().product::<usize>() as u64 * 4)
        .sum();
    match classify(inst) {
        OpClass::Gemm => {
            // flops = 2 * (product of output dims) * K, K from lhs shape
            // minus output contribution.
            let lhs = inst.operands.first().and_then(|o| shapes.get(o));
            let k = match lhs {
                Some(l) => {
                    let lhs_elems: u64 = l.iter().product::<usize>() as u64;
                    let m: u64 = inst.shape.first().copied().unwrap_or(1) as u64;
                    (lhs_elems / m.max(1)).max(1)
                }
                None => 1,
            };
            (2 * out_elems * k, operand_bytes + out_bytes)
        }
        OpClass::Elemwise => (out_elems, operand_bytes + out_bytes),
        OpClass::Reduce => (operand_bytes / 4, operand_bytes + out_bytes),
        OpClass::AdvancedIncSubtensor | OpClass::AdvancedSubtensor => {
            // data movement dominated: touched rows r/w
            (out_elems, operand_bytes + out_bytes)
        }
        OpClass::Alloc | OpClass::Dimshuffle => (0, out_bytes),
        OpClass::Control => (0, 0),
    }
}

/// Aggregate (flops, bytes) per op class over a parsed module.
pub fn module_cost_by_class(
    insts: &[Instruction],
) -> HashMap<OpClass, (u64, u64, u64)> {
    let shapes: HashMap<String, Vec<usize>> =
        insts.iter().map(|i| (i.name.clone(), i.shape.clone())).collect();
    let mut out: HashMap<OpClass, (u64, u64, u64)> = HashMap::new();
    for i in insts {
        let class = classify(i);
        let (f, b) = instruction_cost(i, &shapes);
        let e = out.entry(class).or_insert((0, 0, 0));
        e.0 += f;
        e.1 += b;
        e.2 += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::hlo::parse_hlo;

    #[test]
    fn classes_match_theano_mapping() {
        let mk = |op: &str| Instruction {
            name: "x".into(),
            opcode: op.into(),
            ty: "f32".into(),
            shape: vec![2, 2],
            operands: vec![],
            computation: String::new(),
            is_root: false,
            attrs: String::new(),
        };
        assert_eq!(classify(&mk("scatter")), OpClass::AdvancedIncSubtensor);
        assert_eq!(classify(&mk("dynamic-update-slice")), OpClass::AdvancedIncSubtensor);
        assert_eq!(classify(&mk("gather")), OpClass::AdvancedSubtensor);
        assert_eq!(classify(&mk("tanh")), OpClass::Elemwise);
        assert_eq!(classify(&mk("broadcast")), OpClass::Alloc);
        assert_eq!(classify(&mk("dot")), OpClass::Gemm);
        assert_eq!(classify(&mk("while")), OpClass::Control);
    }

    #[test]
    fn plan_op_labels_map_to_theano_classes() {
        assert_eq!(classify_plan_op("scatter"), OpClass::AdvancedIncSubtensor);
        assert_eq!(classify_plan_op("dynamic-update-slice"), OpClass::AdvancedIncSubtensor);
        assert_eq!(classify_plan_op("gather"), OpClass::AdvancedSubtensor);
        assert_eq!(classify_plan_op("fused-gather"), OpClass::AdvancedSubtensor);
        assert_eq!(classify_plan_op("fused"), OpClass::Elemwise);
        assert_eq!(classify_plan_op("elemwise"), OpClass::Elemwise);
        assert_eq!(classify_plan_op("dot"), OpClass::Gemm);
        assert_eq!(classify_plan_op("fused-dot"), OpClass::Gemm);
        assert_eq!(classify_plan_op("reduce"), OpClass::Reduce);
        assert_eq!(classify_plan_op("fused-reduce"), OpClass::Reduce);
        assert_eq!(classify_plan_op("alloc"), OpClass::Alloc);
        assert_eq!(classify_plan_op("shape"), OpClass::Dimshuffle);
        assert_eq!(classify_plan_op("control"), OpClass::Control);
        for l in ["fused", "fused-reduce", "fused-dot", "fused-gather"] {
            assert!(is_fused_plan_op(l), "{l}");
        }
        assert!(!is_fused_plan_op("dot"));
        assert!(!is_fused_plan_op("elemwise"));
    }

    #[test]
    fn dot_flops() {
        let text = "ENTRY e {\n  a.1 = f32[8,16]{1,0} parameter(0)\n  b.1 = f32[16,4]{1,0} parameter(1)\n  ROOT d.1 = f32[8,4]{1,0} dot(a.1, b.1), lhs_contracting_dims={1}\n}\n";
        let (insts, idx) = parse_hlo(text);
        let shapes: HashMap<String, Vec<usize>> =
            insts.iter().map(|i| (i.name.clone(), i.shape.clone())).collect();
        let (f, _) = instruction_cost(&insts[idx["d.1"]], &shapes);
        assert_eq!(f, 2 * 8 * 4 * 16);
    }

    #[test]
    fn real_train_step_scatter_cost_present() {
        let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/train_step_ref_b16.hlo.txt");
        let text = std::fs::read_to_string(path).expect("make artifacts");
        let (insts, _) = parse_hlo(&text);
        let by_class = module_cost_by_class(&insts);
        assert!(by_class.contains_key(&OpClass::AdvancedIncSubtensor));
        assert!(by_class.contains_key(&OpClass::Gemm));
        let (_, bytes, count) = by_class[&OpClass::AdvancedIncSubtensor];
        assert!(count >= 1);
        assert!(bytes > 0);
    }

    #[test]
    fn theano_names() {
        assert_eq!(
            OpClass::AdvancedIncSubtensor.theano_name(),
            "GpuAdvancedIncSubtensor1"
        );
        assert_eq!(OpClass::Elemwise.theano_name(), "GpuElemwise");
        assert_eq!(OpClass::Alloc.theano_name(), "GpuAlloc");
    }
}
