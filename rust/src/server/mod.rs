//! Embedding/scoring server: the serving-path example of the runtime.
//!
//! A line-oriented TCP protocol (`protocol`), a deadline-based
//! micro-batcher that coalesces concurrent score requests into one
//! artifact dispatch (`batcher`), and the listener wiring (`Server`).
//!
//! Concurrency model: compiled plans are shared (`Compiled` backends
//! are `Sync`), so there is no single executor thread owning the
//! runtime anymore. Each connection gets its own handler thread —
//! handlers block on socket IO, so they must never occupy compute
//! workers — and answers nearest-neighbour queries directly from the
//! shared embedding store (whose Zipf-head hot cache makes the common
//! lookups memory-resident). Score requests flow to one batching loop
//! that executes the shared plan; the execution's kernel fan-out runs
//! on the process-wide worker pool (`util::threadpool::shared`), the
//! same pool the gradient scatter and interpreter use, so serving under
//! load never oversubscribes the machine.

pub mod batcher;
pub mod protocol;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::baselines::model_ref::ModelParams;
use crate::config::ServerCfg;
use crate::embeddings::EmbeddingStore;
use crate::text::Vocab;

use batcher::{BatchExecutor, ScoreRequest};
use protocol::{parse_request, Request, Response};

/// Batch-occupancy histogram buckets: dispatches of `1`, `2`, `3-4`,
/// `5-8`, … requests (power-of-two upper edges), last bucket open.
pub const OCCUPANCY_BUCKETS: usize = 10;

/// Shared server statistics.
#[derive(Default)]
pub struct ServerStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub total_latency_us: AtomicU64,
    /// Score requests shed at admission (queue full, or server
    /// draining) — answered `OVERLOADED` immediately, never queued.
    pub shed: AtomicU64,
    /// Score requests whose queue deadline lapsed before dispatch —
    /// answered `TIMEOUT`, never executed.
    pub timeouts: AtomicU64,
    /// Score requests answered `ERR` because their batch's dispatch
    /// failed or panicked.
    pub dispatch_errors: AtomicU64,
    /// Dispatch counts by coalesced-batch size bucket (see
    /// [`OCCUPANCY_BUCKETS`]).
    pub occupancy: [AtomicU64; OCCUPANCY_BUCKETS],
}

impl ServerStats {
    pub fn mean_latency(&self) -> Duration {
        let n = self.requests.load(Ordering::Relaxed).max(1);
        Duration::from_micros(self.total_latency_us.load(Ordering::Relaxed) / n)
    }

    /// Bucket index for a dispatch that served `n` requests.
    pub fn occupancy_bucket(n: usize) -> usize {
        let n = n.max(1);
        let b = (usize::BITS - (n - 1).leading_zeros()) as usize; // ceil(log2 n)
        b.min(OCCUPANCY_BUCKETS - 1)
    }

    pub fn record_batch(&self, served: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.occupancy[Self::occupancy_bucket(served)].fetch_add(1, Ordering::Relaxed);
    }

    /// `(bucket upper edge, dispatch count)` rows, zeros included.
    pub fn occupancy_histogram(&self) -> Vec<(usize, u64)> {
        (0..OCCUPANCY_BUCKETS)
            .map(|b| (1usize << b, self.occupancy[b].load(Ordering::Relaxed)))
            .collect()
    }
}

pub struct Server {
    pub addr: String,
    stats: Arc<ServerStats>,
    store: Arc<EmbeddingStore>,
    stop: Arc<AtomicBool>,
    listener_thread: Option<std::thread::JoinHandle<()>>,
    batcher_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start serving: compile the shared plans, warm the embedding
    /// store's Zipf-head cache, spawn the batching loop and listener.
    pub fn start(
        cfg: &ServerCfg,
        artifacts_dir: std::path::PathBuf,
        vocab: Vocab,
        params: ModelParams,
    ) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr()?.to_string();
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(ServerStats::default());
        let window = params.window;

        let mut store = EmbeddingStore::from_params(vocab, &params)
            .context("building embedding store")?;
        let hot = crate::util::env::serve_hot_rows().unwrap_or(cfg.hot_rows);
        store.warm(hot).context("warming embedding hot cache")?;
        let store = Arc::new(store);

        let exec = Arc::new(
            BatchExecutor::new(&artifacts_dir, cfg, params)
                .context("building batch executor")?,
        );

        // Batching loop over a *bounded* admission queue: `try_send`
        // from handlers sheds load the instant the queue fills instead
        // of buffering unbounded work the server can't keep up with.
        // On stop the loop keeps dispatching until the queue is drained
        // (graceful shutdown: every admitted request gets an answer).
        let queue_depth = crate::util::env::serve_queue().unwrap_or(cfg.queue_depth).max(1);
        let (score_tx, score_rx) = mpsc::sync_channel::<ScoreRequest>(queue_depth);
        let b_exec = Arc::clone(&exec);
        let b_stats = Arc::clone(&stats);
        let b_stop = Arc::clone(&stop);
        let batcher_thread = std::thread::Builder::new()
            .name("batcher".into())
            .spawn(move || loop {
                let outcome = b_exec.run_once(&score_rx);
                if outcome.served > 0 {
                    b_stats.record_batch(outcome.served);
                }
                if outcome.timed_out > 0 {
                    b_stats.timeouts.fetch_add(outcome.timed_out as u64, Ordering::Relaxed);
                }
                if outcome.failed > 0 {
                    b_stats
                        .dispatch_errors
                        .fetch_add(outcome.failed as u64, Ordering::Relaxed);
                    if let Some(e) = &outcome.error {
                        eprintln!("batcher: dispatch degraded ({e})");
                    }
                }
                if b_stop.load(Ordering::Relaxed) && outcome.is_idle() {
                    return;
                }
            })
            .expect("spawn batcher");

        // Listener: one OS thread per connection. Handlers block on
        // socket reads, so they get real threads, never compute-pool
        // workers (parking a blocked handler on the shared pool would
        // starve the kernels scoring its own request).
        let l_stop = Arc::clone(&stop);
        let l_stats = Arc::clone(&stats);
        let l_store = Arc::clone(&store);
        let listener_thread = std::thread::Builder::new()
            .name("listener".into())
            .spawn(move || loop {
                if l_stop.load(Ordering::Relaxed) {
                    return;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let tx = score_tx.clone();
                        let st = Arc::clone(&l_stats);
                        let store = Arc::clone(&l_store);
                        let conn_stop = Arc::clone(&l_stop);
                        std::thread::Builder::new()
                            .name("conn".into())
                            .spawn(move || {
                                let _ = handle_conn(stream, tx, store, st, window, conn_stop);
                            })
                            .ok();
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    Err(_) => return,
                }
            })
            .expect("spawn listener");

        Ok(Server {
            addr,
            stats,
            store,
            stop,
            listener_thread: Some(listener_thread),
            batcher_thread: Some(batcher_thread),
        })
    }

    pub fn stats(&self) -> &ServerStats {
        &self.stats
    }

    /// Embedding hot-cache (hits, misses) since startup.
    pub fn cache_counters(&self) -> (u64, u64) {
        self.store.cache_counters()
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.listener_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.batcher_thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    score_tx: mpsc::SyncSender<ScoreRequest>,
    store: Arc<EmbeddingStore>,
    stats: Arc<ServerStats>,
    window: usize,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        let t0 = Instant::now();
        let resp = match parse_request(&line, window) {
            Err(msg) => Response::Error(msg),
            Ok(Request::Ping) => Response::Pong,
            Ok(Request::Score(window_ids)) => {
                if stop.load(Ordering::Relaxed) {
                    // Draining: queued work still completes, but no new
                    // score work is admitted.
                    stats.shed.fetch_add(1, Ordering::Relaxed);
                    Response::Overloaded
                } else {
                    let (reply_tx, reply_rx) = mpsc::channel();
                    let req = ScoreRequest {
                        window: window_ids,
                        reply: reply_tx,
                        enqueued: Instant::now(),
                    };
                    match score_tx.try_send(req) {
                        Ok(()) => reply_rx
                            .recv()
                            .unwrap_or(Response::Error("batcher dropped".into())),
                        Err(mpsc::TrySendError::Full(_)) => {
                            // Queue full: shed immediately — an explicit
                            // OVERLOADED beats an unbounded queue whose
                            // tail latency nobody survives.
                            stats.shed.fetch_add(1, Ordering::Relaxed);
                            Response::Overloaded
                        }
                        Err(mpsc::TrySendError::Disconnected(_)) => {
                            return Err(anyhow::anyhow!("batcher gone"));
                        }
                    }
                }
            }
            // NN queries never cross a channel: the store is shared and
            // its hot path is the resident Zipf head. A failed row read
            // (paged backing gone bad) degrades this one request to ERR.
            Ok(Request::Neighbors(word, k)) => match store.neighbors(&word, k) {
                Ok(ns) => Response::Neighbors(ns),
                Err(e) => Response::Error(format!("{e:#}")),
            },
            Ok(Request::Quit) => break,
        };
        stats.requests.fetch_add(1, Ordering::Relaxed);
        stats
            .total_latency_us
            .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        writeln!(writer, "{}", resp.render())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_buckets_cover_powers_of_two() {
        assert_eq!(ServerStats::occupancy_bucket(1), 0);
        assert_eq!(ServerStats::occupancy_bucket(2), 1);
        assert_eq!(ServerStats::occupancy_bucket(3), 2);
        assert_eq!(ServerStats::occupancy_bucket(4), 2);
        assert_eq!(ServerStats::occupancy_bucket(5), 3);
        assert_eq!(ServerStats::occupancy_bucket(8), 3);
        assert_eq!(ServerStats::occupancy_bucket(512), 9);
        assert_eq!(ServerStats::occupancy_bucket(100_000), OCCUPANCY_BUCKETS - 1);
        let s = ServerStats::default();
        s.record_batch(6);
        s.record_batch(1);
        let h = s.occupancy_histogram();
        assert_eq!(h[0], (1, 1));
        assert_eq!(h[3], (8, 1));
        assert_eq!(s.batches.load(Ordering::Relaxed), 2);
    }
}
