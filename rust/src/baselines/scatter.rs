//! Host (pure-Rust) scatter-add baselines.
//!
//! `scatter_add_serial` is the semantic reference (row loop, like Theano's
//! Python implementation); `scatter_add_parallel` shards the *destination*
//! across threads so duplicate indices never race (each thread applies
//! only the updates whose target row falls in its stripe) — the same
//! conflict-avoidance the paper's CUDA kernel achieved with atomics.
//! Benches compare these against the PJRT artifacts.

// Crate-root carve-out (`#![deny(unsafe_code)]` in lib.rs): the parallel
// baseline stripes destination rows across tasks through a raw pointer;
// each unsafe block documents its SAFETY argument.
#![allow(unsafe_code)]

use crate::util::threadpool::par_map;

/// `w[idx[r]] += y[r]` — serial reference.
pub fn scatter_add_serial(w: &mut [f32], d: usize, idx: &[i32], y: &[f32]) {
    assert_eq!(y.len(), idx.len() * d);
    assert!(w.len() % d == 0);
    let v = w.len() / d;
    for (r, &i) in idx.iter().enumerate() {
        let i = i as usize;
        assert!(i < v, "index {i} out of range {v}");
        let dst = &mut w[i * d..(i + 1) * d];
        let src = &y[r * d..(r + 1) * d];
        for (a, b) in dst.iter_mut().zip(src) {
            *a += b;
        }
    }
}

/// Destination-striped parallel scatter-add.
pub fn scatter_add_parallel(w: &mut [f32], d: usize, idx: &[i32], y: &[f32], threads: usize) {
    assert_eq!(y.len(), idx.len() * d);
    let v = w.len() / d;
    let threads = threads.max(1).min(v.max(1));
    let stripe = v.div_ceil(threads);
    // Each task owns rows [t*stripe, (t+1)*stripe) of w; share w unsafely
    // but without overlap.
    struct SendPtr(*mut f32);
    unsafe impl Send for SendPtr {}
    unsafe impl Sync for SendPtr {}
    let wp = SendPtr(w.as_mut_ptr());
    let wp = std::sync::Arc::new(wp);
    let idx: std::sync::Arc<Vec<i32>> = std::sync::Arc::new(idx.to_vec());
    let y: std::sync::Arc<Vec<f32>> = std::sync::Arc::new(y.to_vec());
    par_map(threads, threads, move |t| {
        let lo = t * stripe;
        let hi = ((t + 1) * stripe).min(v);
        let base = wp.0;
        for (r, &i) in idx.iter().enumerate() {
            let i = i as usize;
            if i >= lo && i < hi {
                // SAFETY: rows [lo, hi) are exclusively owned by task t.
                unsafe {
                    let dst = std::slice::from_raw_parts_mut(base.add(i * d), d);
                    for (a, b) in dst.iter_mut().zip(&y[r * d..(r + 1) * d]) {
                        *a += b;
                    }
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::forall;
    use crate::util::rng::Rng;

    fn mk(v: usize, d: usize, r: usize, seed: u64) -> (Vec<f32>, Vec<i32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let w: Vec<f32> = (0..v * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let idx: Vec<i32> = (0..r).map(|_| rng.below(v as u64) as i32).collect();
        let y: Vec<f32> = (0..r * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        (w, idx, y)
    }

    #[test]
    fn serial_accumulates_duplicates() {
        let mut w = vec![0.0f32; 4 * 2];
        let idx = vec![1, 1, 1];
        let y = vec![1.0f32; 6];
        scatter_add_serial(&mut w, 2, &idx, &y);
        assert_eq!(&w[2..4], &[3.0, 3.0]);
        assert_eq!(w.iter().filter(|&&x| x != 0.0).count(), 2);
    }

    #[test]
    fn parallel_matches_serial() {
        for threads in [1, 2, 4, 7] {
            let (w0, idx, y) = mk(100, 8, 300, threads as u64);
            let mut a = w0.clone();
            let mut b = w0;
            scatter_add_serial(&mut a, 8, &idx, &y);
            scatter_add_parallel(&mut b, 8, &idx, &y, threads);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn property_parallel_equals_serial() {
        forall(
            "parallel scatter == serial",
            20,
            |r| (r.below(60) + 2, r.below(6) + 1, r.below(120), r.next_u64()),
            |&(v, d, rows, seed)| {
                let (w0, idx, y) = mk(v as usize, d as usize, rows as usize, seed);
                let mut a = w0.clone();
                let mut b = w0;
                scatter_add_serial(&mut a, d as usize, &idx, &y);
                scatter_add_parallel(&mut b, d as usize, &idx, &y, 3);
                a.iter().zip(&b).all(|(x, y)| (x - y).abs() < 1e-4)
            },
        );
    }

    #[test]
    #[should_panic]
    fn out_of_range_index_panics() {
        let mut w = vec![0.0f32; 4];
        scatter_add_serial(&mut w, 2, &[5], &[1.0, 1.0]);
    }
}
