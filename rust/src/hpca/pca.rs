//! Truncated PCA by randomized subspace iteration (Halko et al. 2011).
//!
//! For X [V, C] (V words, C context features), we want the top-`dim`
//! right-singular directions Q [C, dim] and the embedding X·Q [V, dim].
//! Subspace iteration: start with a random Gaussian block, repeatedly
//! apply XᵀX with QR re-orthonormalization. The X·(XᵀX)-style products are
//! the dominant cost and are parallelized across row blocks with the
//! thread pool — this is the "is it amenable to good parallelization?"
//! question the paper poses, answered in `cargo bench -- e10`.

use anyhow::{bail, Result};

use crate::util::rng::Rng;
use crate::util::threadpool::par_map;

/// Multiply `x [rows, c]` by `q [c, k]` in parallel row blocks.
fn matmul_xq(x: &[f32], rows: usize, c: usize, q: &[f32], k: usize, threads: usize) -> Vec<f32> {
    let block = rows.div_ceil(threads.max(1));
    let x = std::sync::Arc::new(x.to_vec());
    let q = std::sync::Arc::new(q.to_vec());
    let parts = par_map(threads.max(1), threads.max(1), move |t| {
        let lo = t * block;
        let hi = ((t + 1) * block).min(rows);
        let mut out = vec![0.0f32; (hi.saturating_sub(lo)) * k];
        for r in lo..hi {
            let xrow = &x[r * c..(r + 1) * c];
            let orow = &mut out[(r - lo) * k..(r - lo + 1) * k];
            for (j, xv) in xrow.iter().enumerate() {
                if *xv == 0.0 {
                    continue; // hellinger rows are sparse-ish
                }
                let qrow = &q[j * k..(j + 1) * k];
                for (o, qv) in orow.iter_mut().zip(qrow) {
                    *o += xv * qv;
                }
            }
        }
        out
    });
    let mut out = Vec::with_capacity(rows * k);
    for p in parts {
        out.extend(p);
    }
    out
}

/// `xᵀ · y` for x [rows, c], y [rows, k] -> [c, k], parallel over row
/// blocks with per-thread accumulators.
fn matmul_xty(
    x: &[f32],
    rows: usize,
    c: usize,
    y: &[f32],
    k: usize,
    threads: usize,
) -> Vec<f32> {
    let block = rows.div_ceil(threads.max(1));
    let x = std::sync::Arc::new(x.to_vec());
    let y = std::sync::Arc::new(y.to_vec());
    let parts = par_map(threads.max(1), threads.max(1), move |t| {
        let lo = t * block;
        let hi = ((t + 1) * block).min(rows);
        let mut acc = vec![0.0f32; c * k];
        for r in lo..hi {
            let xrow = &x[r * c..(r + 1) * c];
            let yrow = &y[r * k..(r + 1) * k];
            for (j, xv) in xrow.iter().enumerate() {
                if *xv == 0.0 {
                    continue;
                }
                let arow = &mut acc[j * k..(j + 1) * k];
                for (a, yv) in arow.iter_mut().zip(yrow) {
                    *a += xv * yv;
                }
            }
        }
        acc
    });
    let mut out = vec![0.0f32; c * k];
    for p in parts {
        for (o, v) in out.iter_mut().zip(&p) {
            *o += v;
        }
    }
    out
}

/// In-place modified Gram–Schmidt on the columns of `q [c, k]`.
fn qr_orthonormalize(q: &mut [f32], c: usize, k: usize) {
    for j in 0..k {
        // subtract projections onto previous columns
        for prev in 0..j {
            let mut dot = 0.0f32;
            for r in 0..c {
                dot += q[r * k + j] * q[r * k + prev];
            }
            for r in 0..c {
                q[r * k + j] -= dot * q[r * k + prev];
            }
        }
        let mut norm = 0.0f32;
        for r in 0..c {
            norm += q[r * k + j] * q[r * k + j];
        }
        let norm = norm.sqrt();
        if norm > 1e-12 {
            for r in 0..c {
                q[r * k + j] /= norm;
            }
        } else {
            // degenerate column: re-seed deterministically
            let mut rng = Rng::new(0xDEAD ^ j as u64);
            for r in 0..c {
                q[r * k + j] = rng.range_f32(-1.0, 1.0) / (c as f32).sqrt();
            }
        }
    }
}

/// Project `x [rows, c]` onto its top-`dim` principal directions.
/// Returns the embedding [rows, dim].
pub fn project(
    x: &[f32],
    rows: usize,
    c: usize,
    dim: usize,
    iters: usize,
    threads: usize,
    seed: u64,
) -> Result<Vec<f32>> {
    if x.len() != rows * c {
        bail!("x has {} elements, expected {rows}x{c}", x.len());
    }
    if dim > c {
        bail!("dim {dim} exceeds context width {c}");
    }
    let mut rng = Rng::new(seed);
    let mut q: Vec<f32> = (0..c * dim).map(|_| rng.normal() as f32).collect();
    qr_orthonormalize(&mut q, c, dim);
    for _ in 0..iters.max(1) {
        let y = matmul_xq(x, rows, c, &q, dim, threads); // [rows, dim]
        q = matmul_xty(x, rows, c, &y, dim, threads); // XᵀXQ  [c, dim]
        qr_orthonormalize(&mut q, c, dim);
    }
    Ok(matmul_xq(x, rows, c, &q, dim, threads))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a rank-`k` matrix with known spectrum.
    fn low_rank(rows: usize, c: usize, k: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let u: Vec<f32> = (0..rows * k).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..k * c).map(|_| rng.normal() as f32).collect();
        let mut x = vec![0.0f32; rows * c];
        for r in 0..rows {
            for j in 0..c {
                let mut acc = 0.0;
                for t in 0..k {
                    // decaying singular-value-ish weights
                    acc += u[r * k + t] * v[t * c + j] * (1.0 / (1 + t) as f32);
                }
                x[r * c + j] = acc;
            }
        }
        x
    }

    fn frob(x: &[f32]) -> f32 {
        x.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    #[test]
    fn qr_produces_orthonormal_columns() {
        let (c, k) = (20, 5);
        let mut rng = Rng::new(1);
        let mut q: Vec<f32> = (0..c * k).map(|_| rng.normal() as f32).collect();
        qr_orthonormalize(&mut q, c, k);
        for a in 0..k {
            for b in 0..k {
                let mut dot = 0.0f32;
                for r in 0..c {
                    dot += q[r * k + a] * q[r * k + b];
                }
                let expect = if a == b { 1.0 } else { 0.0 };
                assert!((dot - expect).abs() < 1e-4, "({a},{b}) dot {dot}");
            }
        }
    }

    #[test]
    fn projection_captures_low_rank_energy() {
        let (rows, c, k) = (120, 40, 3);
        let x = low_rank(rows, c, k, 2);
        let emb = project(&x, rows, c, k, 4, 2, 7).unwrap();
        // energy captured by the top-k projection should be ~all of ||X||
        // (X is rank k): compare Frobenius norms.
        let ex = frob(&x);
        let ee = frob(&emb);
        assert!(
            (ee / ex) > 0.98,
            "captured energy ratio {:.4}",
            ee / ex
        );
    }

    #[test]
    fn projection_beats_random_directions_on_energy() {
        let (rows, c) = (100, 30);
        let x = low_rank(rows, c, 4, 3);
        let emb = project(&x, rows, c, 2, 4, 2, 7).unwrap();
        // random 2-dim projection captures much less of rank-4 energy
        let mut rng = Rng::new(9);
        let mut q: Vec<f32> = (0..c * 2).map(|_| rng.normal() as f32).collect();
        qr_orthonormalize(&mut q, c, 2);
        let rand_emb = matmul_xq(&x, rows, c, &q, 2, 2);
        assert!(frob(&emb) > 1.2 * frob(&rand_emb));
    }

    #[test]
    fn thread_count_does_not_change_result() {
        // dim == exact rank: the learned subspace is then the full row
        // space, making row norms equal ||x_r|| for any thread count.
        // (With dim > rank the surplus directions are FP-noise-determined
        // and legitimately differ between runs.)
        let (rows, c) = (60, 24);
        let x = low_rank(rows, c, 3, 5);
        let a = project(&x, rows, c, 3, 3, 1, 11).unwrap();
        let b = project(&x, rows, c, 3, 3, 4, 11).unwrap();
        // the basis of the top-k subspace is unique only up to rotation
        // (thread count changes FP summation order), but row norms —
        // the projection lengths — are rotation-invariant.
        for r in 0..rows {
            let na: f32 = a[r * 3..(r + 1) * 3].iter().map(|v| v * v).sum::<f32>().sqrt();
            let nb: f32 = b[r * 3..(r + 1) * 3].iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!((na - nb).abs() < 1e-2 * na.max(1.0), "row {r}: {na} vs {nb}");
        }
    }

    #[test]
    fn shape_validation() {
        assert!(project(&[0.0; 10], 3, 4, 2, 1, 1, 0).is_err());
        assert!(project(&[0.0; 12], 3, 4, 5, 1, 1, 0).is_err());
    }
}
