//! `plan_lint` — the CI correctness gate over the static plan verifier.
//!
//! Sweeps every committed HLO artifact through the full compile matrix —
//! {off, chains, full} fusion × scheduler {on, off} × SIMD {on, off} —
//! and runs each compiled plan through the three-pass checker in
//! `backend::interp::verify` (bytecode abstract interpretation including
//! lane-width/panel-geometry audits, liveness soundness, happens-before
//! race audit). Any error fails the gate; with `--strict` (the CI
//! configuration) warnings fail it too, so the committed artifact set is
//! provably clean, not just clean-enough.
//!
//! ```text
//! plan_lint [DIR] [--strict] [--json PLAN_LINT.json]
//! ```
//!
//! `DIR` defaults to `artifacts` (run from `rust/`, as CI does). The
//! JSON report mirrors the console table — one row per (artifact, fuse,
//! sched, simd) configuration with its step/pair counts and every
//! finding — and is uploaded by the `plan-lint` CI job next to the
//! bench JSON.
//!
//! Exit status: 0 = all plans verified clean, 1 = at least one finding
//! failed the gate, 2 = bad invocation / unreadable artifacts.

use std::collections::BTreeMap;
use std::process::ExitCode;

use polyglot_gpu::backend::interp::parser;
use polyglot_gpu::backend::interp::plan::{self, FuseMode};
use polyglot_gpu::backend::interp::sched::SchedPlan;
use polyglot_gpu::backend::interp::verify::{verify, VerifyMode};
use polyglot_gpu::util::json::Json;

struct Args {
    dir: String,
    strict: bool,
    json: Option<String>,
}

fn parse_args() -> Result<Args, String> {
    let mut args = Args { dir: "artifacts".to_string(), strict: false, json: None };
    let mut dir_set = false;
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--strict" => args.strict = true,
            "--json" => {
                args.json = Some(it.next().ok_or("--json wants a path".to_string())?)
            }
            "--help" | "-h" => {
                return Err(
                    "usage: plan_lint [DIR] [--strict] [--json PLAN_LINT.json]".to_string()
                )
            }
            other if other.starts_with('-') => {
                return Err(format!("unknown argument {other:?} (see --help)"))
            }
            other => {
                if dir_set {
                    return Err(format!("second positional argument {other:?}"));
                }
                args.dir = other.to_string();
                dir_set = true;
            }
        }
    }
    Ok(args)
}

fn artifact_files(dir: &str) -> Result<Vec<std::path::PathBuf>, String> {
    let mut files: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| format!("cannot read artifact dir {dir}: {e}"))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.file_name().is_some_and(|n| n.to_string_lossy().ends_with(".hlo.txt")))
        .collect();
    files.sort();
    if files.is_empty() {
        return Err(format!("no *.hlo.txt artifacts under {dir}"));
    }
    Ok(files)
}

fn fuse_name(mode: FuseMode) -> &'static str {
    match mode {
        FuseMode::Off => "off",
        FuseMode::Chains => "chains",
        FuseMode::Full => "full",
    }
}

struct Row {
    artifact: String,
    fuse: &'static str,
    sched: bool,
    simd: bool,
    steps: usize,
    pairs: usize,
    errors: usize,
    warnings: usize,
    findings: Vec<String>,
}

fn lint(files: &[std::path::PathBuf], strict: bool) -> Result<(Vec<Row>, u32), String> {
    let gate = if strict { VerifyMode::Strict } else { VerifyMode::On };
    let mut rows = Vec::new();
    let mut failures = 0u32;
    for path in files {
        let name = path
            .file_name()
            .map(|n| n.to_string_lossy().trim_end_matches(".hlo.txt").to_string())
            .unwrap_or_default();
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        let module = parser::parse_module(&text)
            .map_err(|e| format!("{name}: parse failed: {e}"))?;
        for mode in [FuseMode::Off, FuseMode::Chains, FuseMode::Full] {
            for simd in [true, false] {
                let compiled = plan::compile_cfg(&module, plan::Config::new(mode, simd))
                    .map_err(|e| format!("{name} [{}]: plan failed: {e}", fuse_name(mode)))?;
                for sched in [true, false] {
                    let sp = sched.then(|| SchedPlan::build(&compiled));
                    let v = verify(&module, &compiled, sp.as_ref());
                    let pass = v.gate(gate).is_ok();
                    if !pass {
                        failures += 1;
                    }
                    let tag = format!(
                        "{name} [fuse={} sched={} simd={}]",
                        fuse_name(mode),
                        if sched { "on" } else { "off" },
                        if simd { "on" } else { "off" }
                    );
                    if pass {
                        println!("  ok   {tag:<56} {}", v.summary());
                    } else {
                        println!("  FAIL {tag}");
                        for line in v.report().lines() {
                            println!("       {line}");
                        }
                    }
                    rows.push(Row {
                        artifact: name.clone(),
                        fuse: fuse_name(mode),
                        sched,
                        simd,
                        steps: v.steps,
                        pairs: v.pairs,
                        errors: v.errors(),
                        warnings: v.warnings(),
                        findings: v.findings.iter().map(|f| f.to_string()).collect(),
                    });
                }
            }
        }
    }
    Ok((rows, failures))
}

fn report_json(rows: &[Row], strict: bool, failures: u32) -> Json {
    let results: Vec<Json> = rows
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("artifact".to_string(), Json::Str(r.artifact.clone()));
            m.insert("fuse".to_string(), Json::Str(r.fuse.to_string()));
            m.insert("sched".to_string(), Json::Bool(r.sched));
            m.insert("simd".to_string(), Json::Bool(r.simd));
            m.insert("steps".to_string(), Json::Num(r.steps as f64));
            m.insert("ordered_pairs".to_string(), Json::Num(r.pairs as f64));
            m.insert("errors".to_string(), Json::Num(r.errors as f64));
            m.insert("warnings".to_string(), Json::Num(r.warnings as f64));
            m.insert(
                "findings".to_string(),
                Json::Arr(r.findings.iter().cloned().map(Json::Str).collect()),
            );
            Json::Obj(m)
        })
        .collect();
    let mut top = BTreeMap::new();
    top.insert("tool".to_string(), Json::Str("plan_lint".to_string()));
    top.insert("strict".to_string(), Json::Bool(strict));
    top.insert("configs".to_string(), Json::Num(rows.len() as f64));
    top.insert("failures".to_string(), Json::Num(failures as f64));
    top.insert("results".to_string(), Json::Arr(results));
    Json::Obj(top)
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return ExitCode::from(2);
        }
    };
    let files = match artifact_files(&args.dir) {
        Ok(f) => f,
        Err(msg) => {
            eprintln!("{msg}");
            return ExitCode::from(2);
        }
    };
    println!(
        "plan_lint: {} artifacts x {{off,chains,full}} x sched {{on,off}} x simd {{on,off}}{}",
        files.len(),
        if args.strict { " (strict: warnings gate)" } else { "" }
    );
    let (rows, failures) = match lint(&files, args.strict) {
        Ok(r) => r,
        Err(msg) => {
            eprintln!("{msg}");
            return ExitCode::from(2);
        }
    };
    if let Some(path) = &args.json {
        let mut text = report_json(&rows, args.strict, failures).render();
        text.push('\n');
        if let Err(e) = std::fs::write(path, text) {
            eprintln!("cannot write {path}: {e}");
            return ExitCode::from(2);
        }
        println!("report written to {path}");
    }
    if failures > 0 {
        eprintln!("plan_lint: {failures} configuration(s) failed verification");
        ExitCode::FAILURE
    } else {
        println!("plan_lint: all {} configurations verified clean", rows.len());
        ExitCode::SUCCESS
    }
}
