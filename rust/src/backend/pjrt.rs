//! PJRT execution backend: the thin adapter from [`Backend`] onto the
//! `xla` crate (`PjRtClient::cpu` → `HloModuleProto::from_text_file` →
//! `compile` → `execute`).
//!
//! Against the vendored API stub, `probe()` fails (compile reports the
//! backend unavailable) and `backend::select` falls back to the
//! interpreter; against a real `xla` binding this is the fast path and
//! nothing above this module changes.

use anyhow::{bail, Context, Result};
use xla::Literal;

use super::{Backend, Buffer, Compiled};
use crate::runtime::manifest::ArtifactSpec;

pub struct PjrtBackend {
    client: xla::PjRtClient,
}

/// A trivial module used to detect whether compile actually works.
const PROBE_HLO: &str = "HloModule probe\n\nENTRY main.2 {\n  ROOT c.1 = f32[] constant(0)\n}\n";

impl PjrtBackend {
    /// Create the backend iff this build can really compile HLO: the
    /// vendored stub errors on `compile`, a native binding compiles the
    /// probe module in microseconds.
    pub fn probe() -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text(PROBE_HLO).context("probe HLO")?;
        client
            .compile(&xla::XlaComputation::from_proto(&proto))
            .context("PJRT compile probe")?;
        Ok(PjrtBackend { client })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn compile(&self, spec: &ArtifactSpec) -> Result<Box<dyn Compiled>> {
        let proto = xla::HloModuleProto::from_text_file(
            spec.file
                .to_str()
                .with_context(|| format!("non-utf8 path {:?}", spec.file))?,
        )
        .with_context(|| format!("parsing HLO text {}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {:?}", spec.name))?;
        Ok(Box::new(PjrtCompiled { exe, untupled: spec.untupled }))
    }
}

struct PjrtCompiled {
    exe: xla::PjRtLoadedExecutable,
    untupled: bool,
}

impl Compiled for PjrtCompiled {
    fn execute(&self, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        let out = self.exe.execute::<&Literal>(inputs).context("PJRT execute")?;
        let root = out[0][0].to_literal_sync().context("fetching result literal")?;
        if self.untupled {
            Ok(vec![root])
        } else {
            root.to_tuple().context("decomposing result tuple")
        }
    }

    fn execute_buffers(&self, args: &[&Buffer]) -> Result<Buffer> {
        let bufs: Vec<&xla::PjRtBuffer> = args
            .iter()
            .map(|b| match b {
                Buffer::Pjrt(p) => Ok(p),
                Buffer::Host(_) => bail!("host buffer passed to the PJRT backend"),
            })
            .collect::<Result<_>>()?;
        let mut out = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&bufs)
            .context("PJRT execute (buffers)")?;
        Ok(Buffer::Pjrt(out[0].swap_remove(0)))
    }

    fn upload(&self, lit: &Literal) -> Result<Buffer> {
        // buffer_from_host_buffer (synchronous kImmutableOnlyDuringCall
        // copy), NOT buffer_from_host_literal: TFRT-CPU's
        // BufferFromHostLiteral copies asynchronously and the literal may
        // be dropped before the copy lands — a use-after-free under rapid
        // per-row dispatch.
        let shape = lit.array_shape().context("upload shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let client = self.exe.client();
        let buf = match shape.ty() {
            xla::ElementType::F32 => client
                .buffer_from_host_buffer(&lit.to_vec::<f32>()?, &dims, None)
                .context("upload f32")?,
            xla::ElementType::S32 => client
                .buffer_from_host_buffer(&lit.to_vec::<i32>()?, &dims, None)
                .context("upload i32")?,
            other => bail!("upload: unsupported dtype {other:?}"),
        };
        Ok(Buffer::Pjrt(buf))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_fails_against_the_stub() {
        // The vendored xla crate cannot compile; a real binding would make
        // this test obsolete (and `select` would prefer PJRT).
        assert!(PjrtBackend::probe().is_err());
    }
}
