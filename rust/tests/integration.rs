//! End-to-end integration: compiled artifacts vs the pure-Rust reference
//! model.
//!
//! Since the Backend refactor these tests execute on every build: the
//! runtime selects PJRT when a real binding is present and the pure-Rust
//! HLO interpreter otherwise, so artifact numerics are asserted — never
//! skipped — in both environments.

use std::path::PathBuf;

use polyglot_gpu::baselines::model_ref::{ModelParams, RefModel};
use polyglot_gpu::config::{Backend, Config, GradMode};
use polyglot_gpu::coordinator::{ModelSize, Trainer};
use polyglot_gpu::data::Batch;
use polyglot_gpu::runtime::{lit_f32, lit_i32, to_scalar_f32, to_vec_f32, Runtime};
use polyglot_gpu::util::rng::Rng;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// A runtime over the committed artifacts. Executes on any build (PJRT or
/// the interpreter fallback); failure to load or compile is a genuinely
/// broken pipeline and fails loudly.
fn runtime() -> Runtime {
    let rt = Runtime::new(&artifacts_dir())
        .expect("committed artifacts must load (regenerate with `make artifacts`)");
    rt.check_execution()
        .expect("artifact execution must work on every build since the Backend refactor");
    rt
}

fn random_batch(rng: &mut Rng, b: usize, c: usize, vocab: usize) -> Batch {
    let windows = (0..b * c).map(|_| rng.below(vocab as u64) as i32).collect();
    let corrupt = (0..b).map(|_| rng.below(vocab as u64) as i32).collect();
    Batch { windows, corrupt, batch: b, window: c }
}

fn cfg_with(backend: Backend, batch: usize) -> Config {
    let mut cfg = Config::default();
    cfg.training.backend = backend;
    cfg.training.batch = batch;
    cfg.training.lr = 0.08;
    cfg.runtime.artifacts_dir = artifacts_dir().to_string_lossy().into_owned();
    cfg
}

/// Max |a-b| over two slices.
fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Bitwise equality of two f32 slices (no tolerance at all).
fn bitwise_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn scatter_artifact_matches_rust_baseline() {
    let rt = runtime();
    let exe = rt.load("scatter_rows_r1000").unwrap();
    let (v, d, r) = (10240usize, 64usize, 1000usize);
    let mut rng = Rng::new(7);
    let w: Vec<f32> = (0..v * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let idx: Vec<i32> = (0..r).map(|_| rng.below(v as u64) as i32).collect();
    let y: Vec<f32> = (0..r * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();

    let out = exe
        .run(&[
            &lit_f32(&w, &[v, d]).unwrap(),
            &lit_i32(&idx, &[r]).unwrap(),
            &lit_f32(&y, &[r, d]).unwrap(),
        ])
        .unwrap();
    let got = to_vec_f32(&out[0]).unwrap();

    let mut expect = w;
    polyglot_gpu::baselines::scatter::scatter_add_serial(&mut expect, d, &idx, &y);
    assert!(max_abs_diff(&got, &expect) < 1e-4);
}

/// Golden equivalence: on the interpreter backend, the serial scatter
/// artifacts (`scatter_native_r*` — XLA scatter op; `scatter_rows_r*` —
/// the lowered per-row kernel loop) must reproduce
/// `baselines::scatter::scatter_add_serial` and the grad subsystem's
/// sharded scatter-add *bitwise*: all four apply f32 row updates in the
/// same stream order.
#[test]
fn interpreter_scatter_bitwise_equals_host_baselines() {
    use polyglot_gpu::config::GradCfg;
    use polyglot_gpu::grad::ScatterEngine;

    let rt = runtime();
    if rt.backend_name() != "interp" {
        // A native PJRT backend owes only tolerance-level agreement
        // (covered above); bitwise reproduction is the interpreter's
        // contract.
        eprintln!("skipping bitwise check: backend is {}", rt.backend_name());
        return;
    }
    let sharded = ScatterEngine::new(&GradCfg {
        mode: GradMode::Sharded,
        threads: 4,
        crossover_rows: 0,
        hot_rows: 8,
    });
    let (v, d) = (10240usize, 64usize);
    let mut rng = Rng::new(41);
    let w: Vec<f32> = (0..v * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let wl = lit_f32(&w, &[v, d]).unwrap();
    for rows in [10usize, 100, 1000] {
        let idx: Vec<i32> = (0..rows).map(|_| rng.below(v as u64) as i32).collect();
        let y: Vec<f32> = (0..rows * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let il = lit_i32(&idx, &[rows]).unwrap();
        let yl = lit_f32(&y, &[rows, d]).unwrap();

        let mut serial = w.clone();
        polyglot_gpu::baselines::scatter::scatter_add_serial(&mut serial, d, &idx, &y);
        let mut shard = w.clone();
        sharded.scatter_add(&mut shard, d, &idx, &y).unwrap();
        assert!(bitwise_eq(&serial, &shard), "sharded vs serial diverge (r={rows})");

        for name in [format!("scatter_native_r{rows}"), format!("scatter_rows_r{rows}")] {
            let out = rt.load(&name).unwrap().run(&[&wl, &il, &yl]).unwrap();
            let got = to_vec_f32(&out[0]).unwrap();
            assert!(
                bitwise_eq(&got, &serial),
                "{name}: interpreter output is not bitwise-equal to the serial baseline"
            );
        }
    }
}

#[test]
fn scatter_all_implementations_agree() {
    let rt = runtime();
    let (v, d, r) = (10240usize, 64usize, 1000usize);
    let mut rng = Rng::new(8);
    let w: Vec<f32> = (0..v * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let idx: Vec<i32> = (0..r).map(|_| rng.below(v as u64) as i32).collect();
    let y: Vec<f32> = (0..r * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let wl = lit_f32(&w, &[v, d]).unwrap();
    let il = lit_i32(&idx, &[r]).unwrap();
    let yl = lit_f32(&y, &[r, d]).unwrap();

    let reference = {
        let out = rt.load("scatter_native_r1000").unwrap().run(&[&wl, &il, &yl]).unwrap();
        to_vec_f32(&out[0]).unwrap()
    };
    for name in [
        "scatter_rows_r1000",
        "scatter_naive_r1000",
        "scatter_onehot_r1000_v512",
    ] {
        let out = rt.load(name).unwrap().run(&[&wl, &il, &yl]).unwrap();
        let got = to_vec_f32(&out[0]).unwrap();
        assert!(max_abs_diff(&got, &reference) < 1e-3, "{name} disagrees");
    }
}

#[test]
fn forward_artifact_matches_ref_model() {
    let rt = runtime();
    let exe = rt.load("forward_b8").unwrap();
    let dims = exe.spec.model.clone().unwrap();
    let p = ModelParams::init(dims.vocab, dims.dim, dims.window, dims.hidden, 3);
    let mut rng = Rng::new(4);
    let batch = random_batch(&mut rng, 8, dims.window, dims.vocab);

    let params = polyglot_gpu::coordinator::upload_params(&p).unwrap();
    let windows = lit_i32(&batch.windows, &[8, dims.window]).unwrap();
    let inputs: Vec<&xla::Literal> = params.iter().chain([&windows]).collect();
    let out = exe.run(&inputs).unwrap();
    let got = to_vec_f32(&out[0]).unwrap();

    let mut m = RefModel::new(&p);
    let expect = m.scores(&p, &batch.windows);
    assert!(max_abs_diff(&got, &expect) < 1e-3, "scores {got:?} vs {expect:?}");
}

#[test]
fn loss_eval_matches_ref_model() {
    let rt = runtime();
    let exe = rt.load("loss_eval_b256").unwrap();
    let dims = exe.spec.model.clone().unwrap();
    let p = ModelParams::init(dims.vocab, dims.dim, dims.window, dims.hidden, 5);
    let mut rng = Rng::new(6);
    let batch = random_batch(&mut rng, 256, dims.window, dims.vocab);

    let params = polyglot_gpu::coordinator::upload_params(&p).unwrap();
    let windows = lit_i32(&batch.windows, &[256, dims.window]).unwrap();
    let corrupt = lit_i32(&batch.corrupt, &[256]).unwrap();
    let inputs: Vec<&xla::Literal> = params.iter().chain([&windows, &corrupt]).collect();
    let loss = to_scalar_f32(&exe.run(&inputs).unwrap()[0]).unwrap();

    let mut m = RefModel::new(&p);
    let expect = m.loss(&p, &batch.windows, &batch.corrupt);
    assert!((loss - expect).abs() < 1e-3, "loss {loss} vs {expect}");
}

#[test]
fn train_step_backends_match_ref_model_and_each_other() {
    let rt = runtime();
    let mut rng = Rng::new(11);

    // host reference
    let dims = rt.manifest.main_model.clone();
    let p0 = ModelParams::init(dims.vocab, dims.dim, dims.window, dims.hidden, 21);
    let batch = random_batch(&mut rng, 16, dims.window, dims.vocab);
    let mut p_ref = p0.clone();
    let mut m = RefModel::new(&p_ref);
    let loss_ref = m.train_step(&mut p_ref, &batch.windows, &batch.corrupt, 0.08);

    let mut results = Vec::new();
    for backend in [Backend::Cpu, Backend::GpuOpt, Backend::GpuNaive] {
        let cfg = cfg_with(backend, 16);
        let mut tr = Trainer::new(Some(&rt), &cfg, ModelSize::Main).unwrap();
        tr.set_params(&p0).unwrap();
        let loss = tr.step(&batch).unwrap();
        assert!(
            (loss - loss_ref).abs() < 1e-3,
            "{}: loss {loss} vs ref {loss_ref}",
            backend.name()
        );
        results.push((backend, tr.params_host().unwrap()));
    }

    for (backend, p) in &results {
        assert!(
            max_abs_diff(&p.e, &p_ref.e) < 2e-3,
            "{}: embeddings diverge from host reference",
            backend.name()
        );
        assert!(max_abs_diff(&p.w1, &p_ref.w1) < 2e-3, "{}: w1", backend.name());
        assert!(max_abs_diff(&p.w2, &p_ref.w2) < 2e-3, "{}: w2", backend.name());
    }
    // backends agree with each other even more tightly
    let (_, pa) = &results[0];
    for (backend, p) in &results[1..] {
        assert!(
            max_abs_diff(&p.e, &pa.e) < 1e-4,
            "{} vs cpu embeddings",
            backend.name()
        );
    }
}

#[test]
fn multi_step_artifact_equals_sequential_steps() {
    let rt = runtime();
    let dims = rt.manifest.main_model.clone();
    let p0 = ModelParams::init(dims.vocab, dims.dim, dims.window, dims.hidden, 31);
    let mut rng = Rng::new(32);
    let batches: Vec<Batch> =
        (0..8).map(|_| random_batch(&mut rng, 16, dims.window, dims.vocab)).collect();

    // fused K=8
    let mut cfg = cfg_with(Backend::GpuOpt, 16);
    cfg.training.fused_steps = 8;
    let mut fused = Trainer::new(Some(&rt), &cfg, ModelSize::Main).unwrap();
    fused.set_params(&p0).unwrap();
    let losses_fused = fused.step_fused(&batches).unwrap();

    // sequential
    let cfg = cfg_with(Backend::GpuOpt, 16);
    let mut seq = Trainer::new(Some(&rt), &cfg, ModelSize::Main).unwrap();
    seq.set_params(&p0).unwrap();
    let losses_seq: Vec<f32> =
        batches.iter().map(|b| seq.step(b).unwrap()).collect();

    for (a, b) in losses_fused.iter().zip(&losses_seq) {
        assert!((a - b).abs() < 1e-4, "losses {losses_fused:?} vs {losses_seq:?}");
    }
    let pf = fused.params_host().unwrap();
    let ps = seq.params_host().unwrap();
    assert!(max_abs_diff(&pf.e, &ps.e) < 1e-4);
}

/// The host backend must reproduce the reference model's SGD step at full
/// model dims, with the gradient fan-out + sharded scatter forced on.
#[test]
fn host_backend_matches_ref_model_step() {
    let mut cfg = cfg_with(Backend::Host, 16);
    cfg.grad.mode = GradMode::Sharded;
    cfg.grad.threads = 8;
    cfg.grad.crossover_rows = 0;
    let mut tr = Trainer::new(None, &cfg, ModelSize::Main).unwrap();
    let dims = tr.dims.clone();
    let p0 = ModelParams::init(dims.vocab, dims.dim, dims.window, dims.hidden, 21);
    tr.set_params(&p0).unwrap();
    let mut rng = Rng::new(11);
    let batch = random_batch(&mut rng, 16, dims.window, dims.vocab);

    let mut p_ref = p0.clone();
    let mut m = RefModel::new(&p_ref);
    let loss_ref = m.train_step(&mut p_ref, &batch.windows, &batch.corrupt, 0.08);

    let loss = tr.step(&batch).unwrap();
    assert!((loss - loss_ref).abs() < 1e-4, "loss {loss} vs ref {loss_ref}");
    let p = tr.params_host().unwrap();
    assert!(max_abs_diff(&p.e, &p_ref.e) < 1e-4, "embeddings diverge");
    assert!(max_abs_diff(&p.w1, &p_ref.w1) < 1e-4, "w1 diverges");
    assert!(max_abs_diff(&p.w2, &p_ref.w2) < 1e-4, "w2 diverges");
}

#[test]
fn training_loss_decreases_end_to_end() {
    // 200 steps of real convergence: runs on the host engine (the same
    // training semantics as the artifact backends, asserted step-for-step
    // above) to keep debug-mode CI time bounded; short artifact training
    // is covered by `artifact_training_smoke` below and the pipeline
    // tests, long-form artifact training by the nightly E1 bench.
    let mut cfg = cfg_with(Backend::Host, 64);
    cfg.training.lr = 0.25;
    let mut tr = Trainer::new(None, &cfg, ModelSize::Main).unwrap();
    let dims = tr.dims.clone();
    let mut rng = Rng::new(77);
    // repeat a small pool of batches so the model can actually fit them
    let pool: Vec<Batch> =
        (0..4).map(|_| random_batch(&mut rng, 64, dims.window, dims.vocab)).collect();
    let mut first = 0.0;
    let mut last = 0.0;
    for i in 0..200 {
        let loss = tr.step(&pool[i % pool.len()]).unwrap();
        if i == 0 {
            first = loss;
        }
        last = loss;
    }
    assert!(last < first * 0.8, "loss {first} -> {last}");
    assert!(tr.metrics.rate() > 0.0);
}

#[test]
fn artifact_training_smoke() {
    // A handful of optimizer steps through the compiled artifact path:
    // loss stays finite, parameters stay finite, and repeating a batch
    // moves the loss down.
    let rt = runtime();
    let cfg = cfg_with(Backend::GpuOpt, 16);
    let mut tr = Trainer::new(Some(&rt), &cfg, ModelSize::Main).unwrap();
    let dims = tr.dims.clone();
    let mut rng = Rng::new(91);
    let batch = random_batch(&mut rng, 16, dims.window, dims.vocab);
    let first = tr.step(&batch).unwrap();
    let mut last = first;
    for _ in 0..5 {
        last = tr.step(&batch).unwrap();
    }
    assert!(first.is_finite() && last.is_finite());
    assert!(last < first, "repeated batch must reduce loss: {first} -> {last}");
    let p = tr.params_host().unwrap();
    assert!(p.e.iter().all(|x| x.is_finite()));
    assert!(p.w1.iter().all(|x| x.is_finite()));
}
