//! Golden equivalence tests for the compiled-plan interpreter.
//!
//! The PR 3 tree-walking evaluator is the semantic reference; the
//! compiled plan (fusion + liveness arena + threaded kernels) must
//! reproduce it on the committed artifacts:
//!
//! * **Scatter artifacts** (`scatter_native_r*`, `scatter_rows_r*`):
//!   bitwise identical across fused/unfused, threads {1, 2, 8}, step
//!   scheduler on/off and SIMD on/off, and bitwise identical to the
//!   *host* serial baseline (`baselines::scatter::scatter_add_serial`)
//!   — the same contract the `grad` subsystem proves in
//!   `tests/grad_equivalence.rs`, now holding through the interpreter's
//!   parallel scatter path too.
//! * **Train-step artifacts** (dot/reduce/gather-heavy, while loops):
//!   within 1e-6 of the tree-walk per output element at every thread
//!   count and lane width (the packed dot and the vectorized lane loops
//!   keep per-element accumulation order, so in practice bitwise).

use std::path::PathBuf;

use polyglot_gpu::backend::interp::plan::FuseMode;
use polyglot_gpu::backend::interp::InterpExecutable;
use polyglot_gpu::baselines::scatter::scatter_add_serial;
use polyglot_gpu::corpus::Zipf;
use polyglot_gpu::runtime::{lit_f32, lit_i32, Manifest};
use polyglot_gpu::testkit::synth_artifact_inputs;
use polyglot_gpu::util::rng::Rng;
use xla::Literal;

/// The full engine matrix the acceptance contract names:
/// {fused(full), fused(chains), unfused} × threads {1, 2, 8} × step
/// scheduler {on, off} × SIMD {on, off}. The scheduler and SIMD legs
/// pin their knobs explicitly via `from_text_simd`, so this matrix
/// holds regardless of the `POLYGLOT_INTERP_SCHED` /
/// `POLYGLOT_INTERP_SIMD` envs CI additionally sweeps. The SIMD-off
/// legs hold scalar kernels and the unpacked dot to the same bars —
/// bitwise on scatter artifacts, 1e-6 on the reassociation-permitted
/// train-step outputs.
const CONFIGS: [(usize, FuseMode, bool, bool); 18] = [
    (1, FuseMode::Full, true, true),
    (2, FuseMode::Full, true, true),
    (8, FuseMode::Full, true, true),
    (2, FuseMode::Full, false, true),
    (8, FuseMode::Full, false, true),
    (1, FuseMode::Full, true, false),
    (8, FuseMode::Full, true, false),
    (2, FuseMode::Full, false, false),
    (1, FuseMode::Chains, true, true),
    (2, FuseMode::Chains, true, true),
    (8, FuseMode::Chains, true, true),
    (8, FuseMode::Chains, true, false),
    (8, FuseMode::Chains, false, true),
    (1, FuseMode::Off, true, true),
    (1, FuseMode::Off, true, false),
    (2, FuseMode::Off, false, true),
    (8, FuseMode::Off, true, true),
    (8, FuseMode::Off, false, false),
];

/// Compile with every knob pinned (the verifier still follows its env
/// default, as before this matrix grew the SIMD axis).
fn build(text: &str, threads: usize, mode: FuseMode, sched: bool, simd: bool) -> InterpExecutable {
    InterpExecutable::from_text_simd(
        text,
        threads,
        mode,
        sched,
        polyglot_gpu::util::env::verify_mode(),
        simd,
    )
    .unwrap()
}

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn artifact_text(manifest: &Manifest, name: &str) -> String {
    let spec = manifest.find(name).unwrap();
    std::fs::read_to_string(&spec.file)
        .unwrap_or_else(|e| panic!("reading {}: {e}", spec.file.display()))
}

/// Duplicate-heavy Zipf inputs for the scatter artifacts: `w[10240,64]`,
/// `idx[rows]` (head-skewed, so shard plans see real contention),
/// `y[rows,64]`.
fn scatter_inputs(rows: usize, seed: u64) -> (Vec<f32>, Vec<i32>, Vec<f32>) {
    let (v, d) = (10240usize, 64usize);
    let z = Zipf::classic(v);
    let mut rng = Rng::new(seed);
    let w: Vec<f32> = (0..v * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let idx: Vec<i32> = (0..rows).map(|_| z.sample(&mut rng) as i32).collect();
    let y: Vec<f32> = (0..rows * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    (w, idx, y)
}

#[test]
fn scatter_artifacts_bitwise_across_threads_and_fusion() {
    let manifest = Manifest::load(&artifacts_dir()).unwrap();
    for rows in [10usize, 100, 1000] {
        let (w, idx, y) = scatter_inputs(rows, 42 + rows as u64);
        let wl = lit_f32(&w, &[10240, 64]).unwrap();
        let il = lit_i32(&idx, &[rows]).unwrap();
        let yl = lit_f32(&y, &[rows, 64]).unwrap();

        // Host golden: serial scatter-add over the same stream.
        let mut golden = w.clone();
        scatter_add_serial(&mut golden, 64, &idx, &y);

        for name in [format!("scatter_native_r{rows}"), format!("scatter_rows_r{rows}")] {
            let text = artifact_text(&manifest, &name);
            let reference = InterpExecutable::from_text_threads(&text, 1)
                .unwrap()
                .run_treewalk(&[&wl, &il, &yl])
                .unwrap();
            let ref_w = reference[0].to_vec::<f32>().unwrap();
            assert_eq!(ref_w, golden, "{name}: tree-walk vs host serial baseline");

            for (threads, mode, sched, simd) in CONFIGS {
                let exe = build(&text, threads, mode, sched, simd);
                let got = exe.run(&[&wl, &il, &yl]).unwrap();
                let got_w = got[0].to_vec::<f32>().unwrap();
                assert_eq!(
                    got_w, ref_w,
                    "{name}: plan (threads={threads}, mode={mode:?}, sched={sched}, \
                     simd={simd}) not bitwise-identical"
                );
            }
        }
    }
}

#[test]
fn train_step_artifacts_match_treewalk_across_threads() {
    let manifest = Manifest::load(&artifacts_dir()).unwrap();
    for name in
        ["train_step_ref_b16", "train_step_ref_b512", "loss_eval_b256", "forward_b256"]
    {
        let mut rng = Rng::new(0xfeed + name.len() as u64);
        let inputs = synth_artifact_inputs(manifest.find(name).unwrap(), &mut rng).unwrap();
        let refs: Vec<&Literal> = inputs.iter().collect();
        let text = artifact_text(&manifest, name);
        let reference =
            InterpExecutable::from_text_threads(&text, 1).unwrap().run_treewalk(&refs).unwrap();
        for (threads, mode, sched, simd) in CONFIGS {
            let exe = build(&text, threads, mode, sched, simd);
            let got = exe.run(&refs).unwrap();
            assert_eq!(got.len(), reference.len(), "{name}: output arity");
            for (o, (g, w)) in got.iter().zip(&reference).enumerate() {
                let gv = g.to_vec::<f32>().unwrap();
                let wv = w.to_vec::<f32>().unwrap();
                assert_eq!(gv.len(), wv.len(), "{name} output {o}");
                for (j, (x, y)) in gv.iter().zip(&wv).enumerate() {
                    assert!(
                        (x - y).abs() <= 1e-6,
                        "{name} (threads={threads}, mode={mode:?}, sched={sched}, \
                         simd={simd}) output {o}[{j}]: {x} vs {y}"
                    );
                }
            }
        }
    }
}

#[test]
fn consumer_fusion_eliminates_steps_on_forward_and_loss_artifacts() {
    // The acceptance metric behind E12's `fusion_coverage`: at Full the
    // plan schedules strictly fewer steps than Chains on the artifacts
    // with reduce-of-elementwise / dot-epilogue / gather-epilogue
    // patterns, and the new step kinds actually fire (fusions can't
    // silently stop).
    let manifest = Manifest::load(&artifacts_dir()).unwrap();
    for name in ["loss_eval_b256", "forward_b256"] {
        let text = artifact_text(&manifest, name);
        let chains = InterpExecutable::from_text_mode(&text, 1, FuseMode::Chains).unwrap();
        let full = InterpExecutable::from_text_mode(&text, 1, FuseMode::Full).unwrap();
        assert!(
            full.plan_step_count() < chains.plan_step_count(),
            "{name}: consumer fusion must eliminate previously-materialized steps \
             ({} vs {})",
            full.plan_step_count(),
            chains.plan_step_count()
        );
        let (fused_full, total) = full.fusion_summary();
        let (fused_chains, _) = chains.fusion_summary();
        assert!(fused_full > 0 && total > 0, "{name}: no fused steps at Full");
        assert!(
            fused_full >= fused_chains,
            "{name}: Full coverage regressed below Chains"
        );
    }
}

#[test]
fn fused_while_loop_artifact_converges_like_treewalk() {
    // scatter_naive_r1000 is the lax.scan (while-loop) variant: per-row
    // dynamic-slice + dynamic-update-slice under heavy control flow —
    // the worst case for the plan's liveness/move schedule. Exact
    // equality expected (pure row copies and adds, no reassociation).
    let manifest = Manifest::load(&artifacts_dir()).unwrap();
    let (w, idx, y) = scatter_inputs(1000, 7);
    let wl = lit_f32(&w, &[10240, 64]).unwrap();
    let il = lit_i32(&idx, &[1000]).unwrap();
    let yl = lit_f32(&y, &[1000, 64]).unwrap();
    let text = artifact_text(&manifest, "scatter_naive_r1000");
    let reference = InterpExecutable::from_text_threads(&text, 1)
        .unwrap()
        .run_treewalk(&[&wl, &il, &yl])
        .unwrap()[0]
        .to_vec::<f32>()
        .unwrap();
    for threads in [1usize, 8] {
        let exe = InterpExecutable::from_text_threads(&text, threads).unwrap();
        let got = exe.run(&[&wl, &il, &yl]).unwrap()[0].to_vec::<f32>().unwrap();
        assert_eq!(got, reference, "threads={threads}");
    }
}
