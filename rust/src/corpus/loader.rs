//! Plain-text corpus loader (one sentence per line) for users with real
//! data; the quickstart example writes and reloads a tiny corpus through
//! this path to prove it.

use std::path::Path;

use anyhow::{Context, Result};

use crate::text::tokenizer::tokenize_lines;

/// Load and tokenize a text file: one sentence per line.
pub fn load_text_file(path: &Path) -> Result<Vec<Vec<String>>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading corpus {}", path.display()))?;
    let sentences = tokenize_lines(&text);
    if sentences.is_empty() {
        anyhow::bail!("corpus {} contains no sentences", path.display());
    }
    Ok(sentences)
}

/// Write sentences to a text file (inverse of `load_text_file`).
pub fn write_text_file(path: &Path, sentences: &[Vec<String>]) -> Result<()> {
    let mut out = String::new();
    for s in sentences {
        out.push_str(&s.join(" "));
        out.push('\n');
    }
    std::fs::write(path, out).with_context(|| format!("writing corpus {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let dir = std::env::temp_dir().join(format!("polyglot-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("corpus.txt");
        let sents = vec![
            vec!["hello".to_string(), "world".to_string()],
            vec!["b".to_string()],
        ];
        write_text_file(&p, &sents).unwrap();
        let loaded = load_text_file(&p).unwrap();
        assert_eq!(loaded, sents);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(load_text_file(Path::new("/nonexistent/corpus.txt")).is_err());
    }

    #[test]
    fn empty_file_errors() {
        let dir = std::env::temp_dir().join(format!("polyglot-test-empty-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("empty.txt");
        std::fs::write(&p, "\n  \n").unwrap();
        assert!(load_text_file(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
