"""Fused dense+tanh hidden layer as a Pallas kernel.

``hidden(x, w1, b1) = tanh(x @ w1 + b1)`` for ``x [B, CD]``, ``w1 [CD, H]``.
This is the Polyglot model's hidden layer; fusing the bias add and tanh into
the matmul epilogue avoids two extra HBM round-trips of the [B, H]
activation (the ``GpuElemwise`` entries that are Table 1's #2 hot spot).

The grid is blocked over the batch so arbitrarily large scoring batches
stream through a fixed VMEM footprint: per step the working set is
``bb·CD + CD·H + H + bb·H`` floats. W1/b1 block index maps return 0, so the
weights stay resident across the batch sweep.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default batch-block: matches the paper's largest swept batch so the
# common train-step instances run as a single grid step.
DEFAULT_BLOCK_B = 512


def _hidden_kernel(x_ref, w1_ref, b1_ref, o_ref):
    acc = jax.lax.dot_general(
        x_ref[...],
        w1_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = jnp.tanh(acc + b1_ref[...][None, :])


def _hidden_pallas(x, w1, b1, *, block_b=DEFAULT_BLOCK_B, interpret=True):
    """Fused ``tanh(x @ w1 + b1)`` with a batch-blocked grid (fwd only)."""
    b, cd = x.shape
    cd2, h = w1.shape
    if cd != cd2:
        raise ValueError(f"x [{b},{cd}] incompatible with w1 [{cd2},{h}]")
    bb = min(block_b, b)
    if b % bb != 0:
        # Fall back to one block; shapes in this repo are powers of two so
        # this only triggers in adversarial tests.
        bb = b
    return pl.pallas_call(
        _hidden_kernel,
        grid=(b // bb,),
        in_specs=[
            pl.BlockSpec((bb, cd), lambda i: (i, 0)),
            pl.BlockSpec((cd, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h), jnp.float32),
        interpret=interpret,
    )(x, w1, b1)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def hidden(x, w1, b1):
    """Differentiable fused hidden layer.

    Forward runs the pallas kernel; the backward pass uses the saved
    activation (``dh = g * (1 - h^2)``) expressed in jnp — the fusion win is
    the forward epilogue, and tanh's derivative reuses the forward output so
    no extra pallas kernel is needed (Pallas calls are not reverse-mode
    differentiable by themselves, hence the custom VJP).
    """
    return _hidden_pallas(x, w1, b1)


def _hidden_fwd(x, w1, b1):
    h = _hidden_pallas(x, w1, b1)
    return h, (x, w1, h)


def _hidden_bwd(res, g):
    x, w1, h = res
    dh = g * (1.0 - h * h)
    return dh @ w1.T, x.T @ dh, dh.sum(axis=0)


hidden.defvjp(_hidden_fwd, _hidden_bwd)
