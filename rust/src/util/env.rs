//! One home for every `POLYGLOT_*` environment knob.
//!
//! Before this module each subsystem parsed its own variable with its
//! own tolerance for typos: the scheduler warned and disabled itself,
//! the profiler silently ignored garbage, the thread knob silently fell
//! back to all cores. Centralizing the parsing gives every knob the
//! same contract:
//!
//! * unset → the documented default;
//! * a recognized value → that value;
//! * anything else → a warning on stderr **and the safest reading for
//!   that knob** (never the value being bisected back on), so a typo in
//!   a CI matrix or a shell session is loud instead of wrong.
//!
//! Each knob has a pure `parse_*` function (unit-tested without touching
//! the process environment) and a thin `*()` reader used by the
//! subsystems. The knobs:
//!
//! | variable                  | values              | default      | typo fallback |
//! |---------------------------|---------------------|--------------|---------------|
//! | `POLYGLOT_INTERP_FUSE`    | `off\|chains\|full` | `full`       | `off`         |
//! | `POLYGLOT_INTERP_SCHED`   | `on\|off`           | `on`         | `off`         |
//! | `POLYGLOT_INTERP_SIMD`    | `on\|off`           | `on`         | `off`         |
//! | `POLYGLOT_INTERP_THREADS` | `0\|1\|2\|…`        | `0` (cores)  | `0` (cores)   |
//! | `POLYGLOT_INTERP_PROFILE` | `on\|off`           | `off`        | `off`         |
//! | `POLYGLOT_INTERP_VERIFY`  | `on\|off\|strict`   | `on` (debug builds), `off` (release) | `on` |
//! | `POLYGLOT_BACKEND`        | `pjrt\|interp`      | probe        | hard error    |
//! | `POLYGLOT_SERVE_MAX_BATCH` | `1\|2\|…`          | config value | config value  |
//! | `POLYGLOT_SERVE_MAX_WAIT_MS` | `0\|1\|…`        | config value | config value  |
//! | `POLYGLOT_SERVE_HOT_ROWS` | `0\|1\|…`           | config value | config value  |
//! | `POLYGLOT_SERVE_IDLE_MS`  | `1\|2\|…`           | `20`         | `20`          |
//! | `POLYGLOT_SERVE_TIMEOUT_MS` | `0\|1\|…`         | config value | config value  |
//! | `POLYGLOT_SERVE_QUEUE`    | `1\|2\|…`           | config value | config value  |
//! | `POLYGLOT_FAILPOINTS`     | `site=mode,…`       | disarmed     | site disarmed |
//!
//! The serving knobs override the corresponding `server.*` config
//! fields at server start (`None` = no override), so a load test can
//! sweep batching policy without editing the config file.
//! `POLYGLOT_FAILPOINTS` is parsed by [`super::failpoint`] (see its
//! module doc for the site list and mode grammar) but shares this
//! module's warn-don't-guess contract for malformed entries.
//!
//! `POLYGLOT_BACKEND` is the one knob where a typo is a hard error
//! rather than a fallback: the caller asked for a *specific* backend and
//! silently probing a different one would defeat the pin.

use anyhow::{bail, Result};

use crate::backend::interp::plan::FuseMode;
use crate::backend::interp::verify::VerifyMode;

/// Variable names, so call sites and error messages never drift.
pub const FUSE: &str = "POLYGLOT_INTERP_FUSE";
pub const SCHED: &str = "POLYGLOT_INTERP_SCHED";
pub const SIMD: &str = "POLYGLOT_INTERP_SIMD";
pub const THREADS: &str = "POLYGLOT_INTERP_THREADS";
pub const PROFILE: &str = "POLYGLOT_INTERP_PROFILE";
pub const VERIFY: &str = "POLYGLOT_INTERP_VERIFY";
pub const BACKEND: &str = "POLYGLOT_BACKEND";
pub const SERVE_MAX_BATCH: &str = "POLYGLOT_SERVE_MAX_BATCH";
pub const SERVE_MAX_WAIT_MS: &str = "POLYGLOT_SERVE_MAX_WAIT_MS";
pub const SERVE_HOT_ROWS: &str = "POLYGLOT_SERVE_HOT_ROWS";
pub const SERVE_IDLE_MS: &str = "POLYGLOT_SERVE_IDLE_MS";
pub const SERVE_TIMEOUT_MS: &str = "POLYGLOT_SERVE_TIMEOUT_MS";
pub const SERVE_QUEUE: &str = "POLYGLOT_SERVE_QUEUE";
pub const FAILPOINTS: &str = "POLYGLOT_FAILPOINTS";

fn var(name: &str) -> Option<String> {
    std::env::var(name).ok()
}

pub(crate) fn warn(name: &str, raw: &str, expected: &str, took: &str) {
    eprintln!("[env] {name}={raw:?} unrecognized (expected {expected}); {took}");
}

/// Shared parser for the small enumerated knobs: match the trimmed,
/// lowercased raw value against `table`; unset or empty takes `default`;
/// anything else warns with `expected`/`took` and returns `fallback` —
/// per the module contract, the safest reading for that knob, never
/// silently the value being bisected back on.
fn enum_knob<T: Copy>(
    name: &str,
    raw: Option<&str>,
    table: &[(&str, T)],
    default: T,
    fallback: T,
    expected: &str,
    took: &str,
) -> T {
    let Some(raw) = raw else { return default };
    let t = raw.trim().to_ascii_lowercase();
    if t.is_empty() {
        return default;
    }
    match table.iter().find(|(k, _)| *k == t) {
        Some(&(_, v)) => v,
        None => {
            warn(name, &t, expected, took);
            fallback
        }
    }
}

/// `POLYGLOT_INTERP_FUSE=off|chains|full` pins the fusion level so a
/// fusion regression can be bisected (`off` = one step per instruction,
/// `chains` = elementwise chains only, `full` = consumer-side fusion —
/// the default). A typo must not silently re-enable the thing being
/// bisected, so unrecognized values compile with fusion OFF.
pub fn fuse_mode() -> FuseMode {
    parse_fuse_mode(var(FUSE).as_deref())
}

pub fn parse_fuse_mode(raw: Option<&str>) -> FuseMode {
    enum_knob(
        FUSE,
        raw,
        &[
            ("off", FuseMode::Off),
            ("0", FuseMode::Off),
            ("chains", FuseMode::Chains),
            ("full", FuseMode::Full),
        ],
        FuseMode::Full,
        FuseMode::Off,
        "off|chains|full",
        "compiling with fusion OFF",
    )
}

/// `POLYGLOT_INTERP_SCHED=on|off` toggles the plan-level parallel
/// scheduler (default **on**; it only engages when the thread budget
/// exceeds 1 and a computation's dependency graph has width ≥ 2).
/// Same typo policy as the fusion knob: unrecognized → scheduler OFF.
pub fn sched() -> bool {
    parse_sched(var(SCHED).as_deref())
}

pub fn parse_sched(raw: Option<&str>) -> bool {
    enum_knob(
        SCHED,
        raw,
        &[("off", false), ("0", false), ("on", true), ("1", true)],
        true,
        false,
        "on|off",
        "scheduler OFF",
    )
}

/// `POLYGLOT_INTERP_SIMD=on|off` pins the kernel lane width the planner
/// bakes into every fused kernel (default **on**: 8-wide chunked lane
/// loops plus the packed cache-blocked dot; `off` compiles every kernel
/// scalar and keeps the unpacked dot). A numerics bisection sets this
/// `off`, so a typo must not re-enable vector code: unrecognized →
/// SIMD OFF.
pub fn simd() -> bool {
    parse_simd(var(SIMD).as_deref())
}

pub fn parse_simd(raw: Option<&str>) -> bool {
    enum_knob(
        SIMD,
        raw,
        &[("off", false), ("0", false), ("on", true), ("1", true)],
        true,
        false,
        "on|off",
        "SIMD OFF",
    )
}

/// Interpreter thread budget: `POLYGLOT_INTERP_THREADS` (0 or unset =
/// all cores). Non-numeric values warn and take the all-cores default.
pub fn threads() -> usize {
    crate::grad::resolve_threads(parse_threads(var(THREADS).as_deref()))
}

pub fn parse_threads(raw: Option<&str>) -> usize {
    let Some(raw) = raw else { return 0 };
    let trimmed = raw.trim();
    if trimmed.is_empty() {
        return 0;
    }
    match trimmed.parse::<usize>() {
        Ok(n) => n,
        Err(_) => {
            warn(THREADS, trimmed, "a thread count (0 = all cores)", "using all cores");
            0
        }
    }
}

/// `POLYGLOT_INTERP_PROFILE=on` turns per-plan-op timing on at compile.
pub fn profile() -> bool {
    parse_profile(var(PROFILE).as_deref())
}

pub fn parse_profile(raw: Option<&str>) -> bool {
    enum_knob(
        PROFILE,
        raw,
        &[("1", true), ("true", true), ("on", true), ("0", false), ("false", false), ("off", false)],
        false,
        false,
        "on|off",
        "profiling OFF",
    )
}

/// `POLYGLOT_INTERP_VERIFY=on|off|strict` gates the static plan
/// verifier (`backend::interp::verify`). Debug builds default **on** —
/// every test compile gets the three verification passes — release
/// builds default off to keep compile latency out of serving paths.
/// `strict` also fails compilation on warnings (the CI `plan_lint`
/// gate). Unlike the bisection knobs, the safe fallback for a typo is
/// to verify *more*, not less: unrecognized values verify ON.
pub fn verify_mode() -> VerifyMode {
    parse_verify_mode(var(VERIFY).as_deref())
}

pub fn parse_verify_mode(raw: Option<&str>) -> VerifyMode {
    let default = if cfg!(debug_assertions) { VerifyMode::On } else { VerifyMode::Off };
    enum_knob(
        VERIFY,
        raw,
        &[
            ("off", VerifyMode::Off),
            ("0", VerifyMode::Off),
            ("on", VerifyMode::On),
            ("1", VerifyMode::On),
            ("true", VerifyMode::On),
            ("strict", VerifyMode::Strict),
        ],
        default,
        VerifyMode::On,
        "on|off|strict",
        "verifier ON",
    )
}

/// Shared parser for the serving overrides: unset/empty → `None` (keep
/// the config value); a number → that override; garbage warns and keeps
/// the config value (the safest reading — never a surprise policy).
fn count_override(name: &str, raw: Option<&str>, min: usize) -> Option<usize> {
    let raw = raw?;
    let trimmed = raw.trim();
    if trimmed.is_empty() {
        return None;
    }
    match trimmed.parse::<usize>() {
        Ok(n) if n >= min => Some(n),
        Ok(_) => {
            warn(name, trimmed, &format!("an integer >= {min}"), "keeping the config value");
            None
        }
        Err(_) => {
            warn(name, trimmed, &format!("an integer >= {min}"), "keeping the config value");
            None
        }
    }
}

/// `POLYGLOT_SERVE_MAX_BATCH=n` caps the micro-batcher's coalesced
/// batch size (≥ 1; overrides `server.max_batch`).
pub fn serve_max_batch() -> Option<usize> {
    parse_serve_max_batch(var(SERVE_MAX_BATCH).as_deref())
}

pub fn parse_serve_max_batch(raw: Option<&str>) -> Option<usize> {
    count_override(SERVE_MAX_BATCH, raw, 1)
}

/// `POLYGLOT_SERVE_MAX_WAIT_MS=n` sets the batch deadline: how long the
/// batcher holds the *first* queued request while coalescing more
/// (overrides `server.max_wait_ms`; 0 = dispatch immediately).
pub fn serve_max_wait_ms() -> Option<u64> {
    parse_serve_max_wait_ms(var(SERVE_MAX_WAIT_MS).as_deref())
}

pub fn parse_serve_max_wait_ms(raw: Option<&str>) -> Option<u64> {
    count_override(SERVE_MAX_WAIT_MS, raw, 0).map(|n| n as u64)
}

/// `POLYGLOT_SERVE_HOT_ROWS=n` pins the embedding store's hot-row cache
/// size (overrides `server.hot_rows`; 0 = no cache — every lookup pages).
pub fn serve_hot_rows() -> Option<usize> {
    parse_serve_hot_rows(var(SERVE_HOT_ROWS).as_deref())
}

pub fn parse_serve_hot_rows(raw: Option<&str>) -> Option<usize> {
    count_override(SERVE_HOT_ROWS, raw, 0)
}

/// `POLYGLOT_SERVE_IDLE_MS=n` sets the batcher's idle poll interval:
/// how long `run_once` blocks for a first request before re-checking
/// the stop flag (≥ 1; default 20 ms). The chaos suite tightens it so
/// shutdown-drain tests don't serialize on the poll.
pub fn serve_idle_ms() -> u64 {
    parse_serve_idle_ms(var(SERVE_IDLE_MS).as_deref())
}

pub fn parse_serve_idle_ms(raw: Option<&str>) -> u64 {
    count_override(SERVE_IDLE_MS, raw, 1).map(|n| n as u64).unwrap_or(20)
}

/// `POLYGLOT_SERVE_TIMEOUT_MS=n` sets the per-request deadline: a
/// request still queued when `enqueued + n` ms lapse is answered
/// `TIMEOUT` and never executed (overrides `server.timeout_ms`;
/// 0 = deadlines off).
pub fn serve_timeout_ms() -> Option<u64> {
    parse_serve_timeout_ms(var(SERVE_TIMEOUT_MS).as_deref())
}

pub fn parse_serve_timeout_ms(raw: Option<&str>) -> Option<u64> {
    count_override(SERVE_TIMEOUT_MS, raw, 0).map(|n| n as u64)
}

/// `POLYGLOT_SERVE_QUEUE=n` bounds the admission queue between the
/// connection handlers and the batcher (≥ 1; overrides
/// `server.queue_depth`). A full queue sheds: the request is answered
/// `OVERLOADED` immediately instead of growing the backlog.
pub fn serve_queue() -> Option<usize> {
    parse_serve_queue(var(SERVE_QUEUE).as_deref())
}

pub fn parse_serve_queue(raw: Option<&str>) -> Option<usize> {
    count_override(SERVE_QUEUE, raw, 1)
}

/// The backend pin: `POLYGLOT_BACKEND=pjrt|interp`. `None` means "no
/// pin — probe". Unrecognized values are a hard error (see module doc).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendPin {
    Pjrt,
    Interp,
}

pub fn backend_pin() -> Result<Option<BackendPin>> {
    parse_backend_pin(var(BACKEND).as_deref())
}

pub fn parse_backend_pin(raw: Option<&str>) -> Result<Option<BackendPin>> {
    let Some(raw) = raw else { return Ok(None) };
    match raw.trim().to_ascii_lowercase().as_str() {
        "pjrt" => Ok(Some(BackendPin::Pjrt)),
        "interp" => Ok(Some(BackendPin::Interp)),
        other => bail!("{BACKEND}={other:?} (expected pjrt | interp)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fuse_mode_accepts_documented_values() {
        assert_eq!(parse_fuse_mode(None), FuseMode::Full);
        assert_eq!(parse_fuse_mode(Some("")), FuseMode::Full);
        assert_eq!(parse_fuse_mode(Some("full")), FuseMode::Full);
        assert_eq!(parse_fuse_mode(Some(" FULL ")), FuseMode::Full);
        assert_eq!(parse_fuse_mode(Some("chains")), FuseMode::Chains);
        assert_eq!(parse_fuse_mode(Some("off")), FuseMode::Off);
        assert_eq!(parse_fuse_mode(Some("0")), FuseMode::Off);
    }

    #[test]
    fn fuse_mode_typo_disables_fusion() {
        // A typo must not silently re-enable the thing being bisected.
        assert_eq!(parse_fuse_mode(Some("fulll")), FuseMode::Off);
        assert_eq!(parse_fuse_mode(Some("yes")), FuseMode::Off);
    }

    #[test]
    fn sched_accepts_documented_values() {
        assert!(parse_sched(None));
        assert!(parse_sched(Some("")));
        assert!(parse_sched(Some("on")));
        assert!(parse_sched(Some("1")));
        assert!(!parse_sched(Some("off")));
        assert!(!parse_sched(Some("0")));
        assert!(!parse_sched(Some(" OFF ")));
    }

    #[test]
    fn sched_typo_disables_scheduler() {
        assert!(!parse_sched(Some("onn")));
        assert!(!parse_sched(Some("enabled")));
    }

    #[test]
    fn simd_accepts_documented_values() {
        assert!(parse_simd(None));
        assert!(parse_simd(Some("")));
        assert!(parse_simd(Some("on")));
        assert!(parse_simd(Some("1")));
        assert!(!parse_simd(Some("off")));
        assert!(!parse_simd(Some("0")));
        assert!(!parse_simd(Some(" OFF ")));
    }

    #[test]
    fn simd_typo_disables_vector_code() {
        // A numerics bisection runs with SIMD off; a typo must not
        // silently hand the vector kernels back.
        assert!(!parse_simd(Some("onn")));
        assert!(!parse_simd(Some("avx")));
    }

    #[test]
    fn threads_parses_counts_and_falls_back_on_garbage() {
        assert_eq!(parse_threads(None), 0);
        assert_eq!(parse_threads(Some("")), 0);
        assert_eq!(parse_threads(Some("0")), 0);
        assert_eq!(parse_threads(Some(" 8 ")), 8);
        assert_eq!(parse_threads(Some("many")), 0);
        assert_eq!(parse_threads(Some("-2")), 0);
    }

    #[test]
    fn profile_accepts_documented_values() {
        assert!(!parse_profile(None));
        assert!(!parse_profile(Some("")));
        assert!(!parse_profile(Some("off")));
        assert!(parse_profile(Some("1")));
        assert!(parse_profile(Some("true")));
        assert!(parse_profile(Some("on")));
        assert!(!parse_profile(Some("yes")), "garbage must not enable profiling");
    }

    #[test]
    fn verify_mode_defaults_follow_build_profile() {
        let default = parse_verify_mode(None);
        if cfg!(debug_assertions) {
            assert_eq!(default, VerifyMode::On);
        } else {
            assert_eq!(default, VerifyMode::Off);
        }
        assert_eq!(parse_verify_mode(Some("")), default);
    }

    #[test]
    fn verify_mode_accepts_documented_values() {
        assert_eq!(parse_verify_mode(Some("off")), VerifyMode::Off);
        assert_eq!(parse_verify_mode(Some("0")), VerifyMode::Off);
        assert_eq!(parse_verify_mode(Some("on")), VerifyMode::On);
        assert_eq!(parse_verify_mode(Some("1")), VerifyMode::On);
        assert_eq!(parse_verify_mode(Some("STRICT")), VerifyMode::Strict);
    }

    #[test]
    fn verify_mode_typo_fails_safe_to_on() {
        // Opposite polarity from the bisection knobs: when in doubt,
        // check more.
        assert_eq!(parse_verify_mode(Some("strct")), VerifyMode::On);
    }

    #[test]
    fn serve_overrides_parse_counts_and_keep_config_on_garbage() {
        assert_eq!(parse_serve_max_batch(None), None);
        assert_eq!(parse_serve_max_batch(Some("")), None);
        assert_eq!(parse_serve_max_batch(Some(" 64 ")), Some(64));
        assert_eq!(parse_serve_max_batch(Some("0")), None, "a zero batch cap is garbage");
        assert_eq!(parse_serve_max_batch(Some("lots")), None);
        assert_eq!(parse_serve_max_wait_ms(None), None);
        assert_eq!(parse_serve_max_wait_ms(Some("0")), Some(0), "0 = dispatch immediately");
        assert_eq!(parse_serve_max_wait_ms(Some("25")), Some(25));
        assert_eq!(parse_serve_max_wait_ms(Some("-3")), None);
        assert_eq!(parse_serve_hot_rows(None), None);
        assert_eq!(parse_serve_hot_rows(Some("0")), Some(0), "0 = cache off, a valid pin");
        assert_eq!(parse_serve_hot_rows(Some("4096")), Some(4096));
        assert_eq!(parse_serve_hot_rows(Some("all")), None);
    }

    #[test]
    fn idle_timeout_queue_knobs_parse_and_fall_back() {
        assert_eq!(parse_serve_idle_ms(None), 20);
        assert_eq!(parse_serve_idle_ms(Some("")), 20);
        assert_eq!(parse_serve_idle_ms(Some(" 2 ")), 2);
        assert_eq!(parse_serve_idle_ms(Some("0")), 20, "a zero idle poll would spin");
        assert_eq!(parse_serve_idle_ms(Some("soon")), 20);
        assert_eq!(parse_serve_timeout_ms(None), None);
        assert_eq!(parse_serve_timeout_ms(Some("0")), Some(0), "0 = deadlines off, a valid pin");
        assert_eq!(parse_serve_timeout_ms(Some("40")), Some(40));
        assert_eq!(parse_serve_timeout_ms(Some("-1")), None);
        assert_eq!(parse_serve_queue(None), None);
        assert_eq!(parse_serve_queue(Some("256")), Some(256));
        assert_eq!(parse_serve_queue(Some("0")), None, "a zero-depth queue admits nothing");
        assert_eq!(parse_serve_queue(Some("deep")), None);
    }

    #[test]
    fn backend_pin_parses_or_errors() {
        assert_eq!(parse_backend_pin(None).unwrap(), None);
        assert_eq!(parse_backend_pin(Some("pjrt")).unwrap(), Some(BackendPin::Pjrt));
        assert_eq!(parse_backend_pin(Some("interp")).unwrap(), Some(BackendPin::Interp));
        let err = parse_backend_pin(Some("cuda")).unwrap_err().to_string();
        assert!(err.contains("POLYGLOT_BACKEND"), "{err}");
        assert!(err.contains("pjrt | interp"), "{err}");
    }
}
