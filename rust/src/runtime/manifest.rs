//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes `artifacts/manifest.json`) and the Rust runtime (which loads it).
//!
//! Every artifact entry carries its full input/output tensor specs so the
//! runtime can validate literals before dispatch — shape bugs surface as
//! named errors here instead of opaque PJRT aborts.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    S32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "s32" => DType::S32,
            _ => bail!("unknown dtype {s:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::S32 => "s32",
        }
    }

    pub fn bytes(&self) -> usize {
        4
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.elements() * self.dtype.bytes()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.req("name")?.as_str().context("spec name")?.to_string(),
            dtype: DType::parse(j.req("dtype")?.as_str().context("spec dtype")?)?,
            shape: j
                .req("shape")?
                .as_arr()
                .context("spec shape")?
                .iter()
                .map(|v| v.as_usize().context("shape dim"))
                .collect::<Result<_>>()?,
        })
    }
}

/// Model dims an artifact was baked with.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelDims {
    pub vocab: usize,
    pub dim: usize,
    pub window: usize,
    pub hidden: usize,
}

impl ModelDims {
    fn from_json(j: &Json) -> Result<ModelDims> {
        Ok(ModelDims {
            vocab: j.req("vocab")?.as_usize().context("vocab")?,
            dim: j.req("dim")?.as_usize().context("dim")?,
            window: j.req("window")?.as_usize().context("window")?,
            hidden: j.req("hidden")?.as_usize().context("hidden")?,
        })
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub kind: String,
    pub backend: Option<String>,
    pub batch: Option<usize>,
    pub k: Option<usize>,
    pub rows: Option<usize>,
    pub model: Option<ModelDims>,
    /// Root is a plain array (return_tuple=False): outputs come back as a
    /// single array buffer usable directly with `execute_b`.
    pub untupled: bool,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub main_model: ModelDims,
    pub small_model: ModelDims,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let version = j.req("version")?.as_i64().context("version")?;
        if version != 1 {
            bail!("manifest version {version} unsupported (expected 1)");
        }
        let mut artifacts = Vec::new();
        for a in j.req("artifacts")?.as_arr().context("artifacts array")? {
            let name = a.req("name")?.as_str().context("name")?.to_string();
            let parse = || -> Result<ArtifactSpec> {
                Ok(ArtifactSpec {
                    name: name.clone(),
                    file: dir.join(a.req("file")?.as_str().context("file")?),
                    kind: a.req("kind")?.as_str().context("kind")?.to_string(),
                    backend: a.get("backend").and_then(|v| v.as_str()).map(String::from),
                    batch: a.get("batch").and_then(|v| v.as_usize()),
                    k: a.get("k").and_then(|v| v.as_usize()),
                    rows: a.get("rows").and_then(|v| v.as_usize()),
                    model: match a.get("model") {
                        Some(m) => Some(ModelDims::from_json(m)?),
                        None => None,
                    },
                    untupled: a.get("untupled").and_then(|v| v.as_bool()).unwrap_or(false),
                    inputs: a
                        .req("inputs")?
                        .as_arr()
                        .context("inputs")?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<_>>()?,
                    outputs: a
                        .req("outputs")?
                        .as_arr()
                        .context("outputs")?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<_>>()?,
                })
            };
            artifacts.push(parse().with_context(|| format!("artifact {name:?}"))?);
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            main_model: ModelDims::from_json(j.req("main_model")?)?,
            small_model: ModelDims::from_json(j.req("small_model")?)?,
            artifacts,
        })
    }

    pub fn find(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| {
                let have: Vec<_> = self.artifacts.iter().map(|a| a.name.as_str()).collect();
                anyhow!("artifact {name:?} not in manifest (have: {have:?})")
            })
    }

    /// Name of a train-step artifact for (backend tag, batch).
    pub fn train_step_name(tag: &str, batch: usize, small: bool) -> String {
        if small {
            format!("train_small_{tag}_b{batch}")
        } else if tag == "naive" {
            format!("train_naive_b{batch}")
        } else {
            format!("train_step_{tag}_b{batch}")
        }
    }

    /// All batch sizes available for a given train family.
    pub fn batches_for(&self, kind: &str, backend: Option<&str>) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == kind && (backend.is_none() || a.backend.as_deref() == backend))
            .filter_map(|a| a.batch)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // tests run from the crate root
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let m = Manifest::load(&artifacts_dir()).expect("run `make artifacts` first");
        assert!(m.artifacts.len() >= 30, "only {} artifacts", m.artifacts.len());
        assert_eq!(m.main_model.window, 5);
        assert_eq!(m.small_model.vocab, 2048);
    }

    #[test]
    fn finds_expected_families() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        for name in [
            "train_step_opt_b16",
            "train_step_ref_b16",
            "train_naive_b16",
            "train_multi_opt_b16_k8",
            "scatter_rows_r1000",
            "scatter_row1_main",
            "forward_b8",
            "loss_eval_b256",
        ] {
            let a = m.find(name).unwrap();
            assert!(a.file.exists(), "{} missing", a.file.display());
            assert!(!a.inputs.is_empty());
            assert!(!a.outputs.is_empty());
        }
        assert!(m.find("nonexistent").is_err());
    }

    #[test]
    fn train_step_specs_consistent() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let a = m.find("train_step_opt_b16").unwrap();
        let md = a.model.as_ref().unwrap();
        assert_eq!(a.inputs.len(), 8); // 5 params + windows + corrupt + lr
        assert_eq!(a.outputs.len(), 6); // 5 params + loss
        assert_eq!(a.inputs[0].shape, vec![md.vocab, md.dim]);
        assert_eq!(a.inputs[5].shape, vec![16, md.window]);
        assert_eq!(a.inputs[5].dtype, DType::S32);
        assert_eq!(a.outputs[5].shape, Vec::<usize>::new()); // scalar loss
    }

    #[test]
    fn batch_sweep_present() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let batches = m.batches_for("train_step", Some("opt"));
        assert_eq!(batches, vec![16, 32, 64, 128, 256, 512]);
    }

    #[test]
    fn train_step_name_builder() {
        assert_eq!(Manifest::train_step_name("opt", 16, false), "train_step_opt_b16");
        assert_eq!(Manifest::train_step_name("naive", 16, false), "train_naive_b16");
        assert_eq!(Manifest::train_step_name("opt", 64, true), "train_small_opt_b64");
    }
}
