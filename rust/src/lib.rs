//! # polyglot-gpu
//!
//! Reproduction of *"Exploring the power of GPU's for training Polyglot
//! language models"* (Kulkarni, Al-Rfou', Perozzi, Skiena — 2014) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! - **L1** (`python/compile/kernels/`): Pallas kernels for advanced
//!   indexing (the paper's hot spot) and the fused hidden layer.
//! - **L2** (`python/compile/model.py`): the Polyglot window model,
//!   AOT-lowered to HLO text artifacts.
//! - **L3** (this crate): the coordinator — data pipeline, batching,
//!   training loop, Theano-style profiler, GPU device model, serving.
//!
//! See DESIGN.md for the architecture and the per-experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

// Unsafe is denied crate-wide rather than forbidden: exactly four modules
// carry a reviewed `#![allow(unsafe_code)]` carve-out for disjoint-range
// parallel writes and scoped-lifetime erasure over the crate thread pool
// (util::threadpool, backend::interp::kernels, grad::sharded,
// baselines::scatter — each unsafe block documents its SAFETY argument).
// Everything else, the verifier and planner included, is safe Rust; a new
// `unsafe` outside those files is a compile error, not a review note.
#![deny(unsafe_code)]

pub mod backend;
pub mod baselines;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod corpus;
pub mod data;
pub mod devicemodel;
pub mod distributed;
pub mod embeddings;
pub mod eval;
pub mod grad;
pub mod hpca;
pub mod profiler;
pub mod runtime;
pub mod server;
pub mod testkit;
pub mod text;
pub mod util;
