//! Elementwise fusion: chains of `add`/`multiply`/`compare`/`select`/
//! `convert`/... collapse into one loop kernel.
//!
//! The tree-walker materializes a full tensor per SSA value, so a chain
//! of N elementwise ops makes N passes over memory with N allocations.
//! The plan compiler instead lowers each maximal single-consumer chain
//! into a small postfix **expression bytecode** ([`EInstr`]), executed
//! block-by-block ([`BLOCK`] elements at a time): inputs are read once,
//! intermediates live in a recycled per-block stack that stays in cache,
//! and exactly one output tensor is written.
//!
//! Scalar semantics come from [`super::eval`]'s op tables (`bin_f32`,
//! `un_f32`, ...), so a fused chain is **bitwise identical** to the
//! unfused walk — elementwise ops are order-free per element and both
//! paths apply the very same `fn(f32, f32) -> f32`.
//!
//! Broadcasts participate as leaves instead of materializing planes:
//! `broadcast`-of-scalar pushes one pre-read value per block
//! ([`EInstr::Splat`]); for rank-2 chains a row-vector broadcast along
//! the trailing dim ([`EInstr::Tile`], the bias-add pattern) and a
//! column-vector broadcast along the leading dim ([`EInstr::Rep`], the
//! per-row validity mask pattern) read their small source in place with
//! modular index math, valid at *any* block offset.
//!
//! **Consumer-side fusion** builds on the same bytecode through
//! [`FusedCtx`]: a prepared, `Sync` evaluation context whose
//! [`FusedCtx::eval_block`] computes an arbitrary element range, with
//! any number of kernel inputs supplied as *hot blocks* ([`BlockSlice`])
//! by the calling kernel — how `dot`/`gather` stream their
//! freshly-computed rows through an epilogue chain (several dots may
//! stream into one chain), how `reduce` folds a prologue chain per
//! block without ever materializing its input ([`super::kernels`]), and
//! how a reduce's own epilogue chain consumes the folded value. The
//! same mechanism powers **in-place fused outputs**
//! ([`run_fused_in_place`]): a dying same-shape input buffer is
//! re-presented as the hot block while the finished block overwrites it
//! — safe because block `[lo, hi)` is written only after every read of
//! `[lo, hi)`, and later blocks never read earlier elements.
//!
//! **Lane vectorization:** when a kernel is compiled with
//! [`FusedKernel::lanes`] = 8 (the `POLYGLOT_INTERP_SIMD` default), the
//! f32/i32 `Bin`/`Un` opcodes run explicit [`LANES`]-wide chunked
//! kernels (fixed-size array views the optimizer turns into SIMD; no
//! intrinsics, no unsafe) with a scalar remainder tail. Per element the
//! chunked body applies the *same* scalar function in the *same*
//! operand order, so results stay bitwise identical to the scalar loop
//! — there is no reassociation here. `Cmp`/`Sel`/`Cvt` and pred lanes
//! keep the scalar path outright, and `Splat`/`Tile`/`Rep` were already
//! bulk fills.

use std::cell::Cell;

use anyhow::{anyhow, bail, Result};

use super::eval::{self, bin_f32, bin_i32, bin_pred, un_f32};
use super::parser::{BinOp, CmpDir, Computation, Op, Shape, UnOp};
use super::value::{Data, Tensor, Ty};

/// Elements processed per block: big enough to amortize dispatch, small
/// enough that a whole stack of lanes stays in L1/L2.
pub const BLOCK: usize = 1024;

/// Chunk width of the vectorized lane loops (`f32x8`-style: eight-lane
/// fixed-size array bodies the optimizer lowers to SIMD).
pub const LANES: usize = 8;

/// One postfix bytecode instruction of a fused kernel.
#[derive(Clone, Debug)]
pub enum EInstr {
    /// Push a block of external input `k`.
    Load(u16),
    /// Push external scalar input `k`, splatted across the block.
    Splat(u16),
    /// Push external row-vector input `k` (length [`FusedKernel::inner`])
    /// tiled along the trailing dim: element `i` reads `src[i % inner]`.
    Tile(u16),
    /// Push external column-vector input `k` repeated along the trailing
    /// dim: element `i` reads `src[i / inner]`.
    Rep(u16),
    /// Pop rhs, pop lhs, push the elementwise binary result.
    Bin(BinOp),
    /// Pop rhs, pop lhs, push the elementwise comparison (pred).
    Cmp(CmpDir),
    /// Pop on_false, pop on_true, pop pred, push the selection.
    Sel,
    /// Apply a unary op to the top of stack in place.
    Un(UnOp),
    /// Pop a lane, push it converted to the given type.
    Cvt(Ty),
}

/// A compiled elementwise chain: one pass over memory instead of one
/// materialized tensor per fused instruction.
pub struct FusedKernel {
    pub prog: Vec<EInstr>,
    pub n_inputs: usize,
    pub out_ty: Ty,
    /// Trailing-dim length of the (rank-2) chain shape — the period for
    /// `Tile`/`Rep` leaves. 0 when the chain has no such leaf.
    pub inner: usize,
    /// Lane width of the f32/i32 `Bin`/`Un` loops: [`LANES`] (chunked
    /// vectorized bodies) or 1 (plain scalar, the
    /// `POLYGLOT_INTERP_SIMD=off` pin). Bitwise identical either way.
    pub lanes: u8,
    /// HLO opcodes folded into this kernel, postfix order (diagnostics
    /// and fuser tests).
    pub ops: Vec<&'static str>,
}

// ------------------------------------------------------------ fusability

/// Is this op an elementwise candidate (same-shape, one output element
/// per input element)?
pub fn is_elementwise(op: &Op) -> bool {
    matches!(
        op,
        Op::Binary(_) | Op::Unary(_) | Op::Compare { .. } | Op::Select | Op::Convert
    )
}

fn arr_of(shape: &Shape) -> Option<(Ty, &[usize])> {
    match shape {
        Shape::Arr(ty, dims) => Some((*ty, dims)),
        Shape::Tuple(_) => None,
    }
}

/// Can instruction `i` be a member (interior or root) of a fused chain?
/// Checks the static op/type/shape legality the bytecode relies on, so
/// kernel compilation cannot fail on a node this accepts.
pub fn fusable_node(comp: &Computation, i: usize) -> bool {
    let ins = &comp.instrs[i];
    if !is_elementwise(&ins.op) {
        return false;
    }
    let Some((ty, dims)) = arr_of(&ins.shape) else { return false };
    let opnd = |j: usize| -> Option<(Ty, &[usize])> {
        let o = *ins.operands.get(j)?;
        arr_of(&comp.instrs[o].shape)
    };
    match &ins.op {
        Op::Binary(b) => {
            let (Some((ta, da)), Some((tb, db))) = (opnd(0), opnd(1)) else { return false };
            if ta != tb || ta != ty || da != dims || db != dims {
                return false;
            }
            match ta {
                Ty::F32 => bin_f32(*b).is_ok(),
                Ty::S32 => bin_i32(*b).is_ok(),
                Ty::Pred => bin_pred(*b).is_ok(),
            }
        }
        Op::Unary(u) => {
            let Some((ta, da)) = opnd(0) else { return false };
            if ta != ty || da != dims {
                return false;
            }
            matches!((ta, u), (Ty::F32, _) | (Ty::S32, UnOp::Neg))
        }
        Op::Compare { .. } => {
            let (Some((ta, da)), Some((tb, db))) = (opnd(0), opnd(1)) else { return false };
            ta == tb && ta != Ty::Pred && da == dims && db == dims && ty == Ty::Pred
        }
        Op::Select => {
            let (Some((tp, dp)), Some((tt, dt)), Some((tf, df))) =
                (opnd(0), opnd(1), opnd(2))
            else {
                return false;
            };
            tp == Ty::Pred && tt == tf && tt == ty && dp == dims && dt == dims && df == dims
        }
        Op::Convert => {
            let Some((_, da)) = opnd(0) else { return false };
            ty != Ty::Pred && da == dims
        }
        _ => false,
    }
}

/// Is instruction `i` a broadcast of a scalar (fusable as a `Splat`
/// leaf)? The consumer-side dims check lives in the plan compiler.
pub fn splat_node(comp: &Computation, i: usize) -> bool {
    let ins = &comp.instrs[i];
    let Op::Broadcast { .. } = &ins.op else { return false };
    let Some((ty, _)) = arr_of(&ins.shape) else { return false };
    let Some(&o) = ins.operands.first() else { return false };
    match arr_of(&comp.instrs[o].shape) {
        Some((oty, odims)) => oty == ty && odims.iter().product::<usize>() == 1,
        None => false,
    }
}

/// Is instruction `i` a rank-2 broadcast of a row vector along the
/// trailing dim (`dimensions={1}`, the bias-add pattern — fusable as a
/// `Tile` leaf)?
pub fn tile_node(comp: &Computation, i: usize) -> bool {
    let ins = &comp.instrs[i];
    let Op::Broadcast { dims: map } = &ins.op else { return false };
    let Some((ty, od)) = arr_of(&ins.shape) else { return false };
    let Some(&o) = ins.operands.first() else { return false };
    let Some((oty, sd)) = arr_of(&comp.instrs[o].shape) else { return false };
    oty == ty
        && od.len() == 2
        && sd.len() == 1
        && map.len() == 1
        && map[0] == 1
        && sd[0] == od[1]
}

/// Is instruction `i` a rank-2 broadcast of a column vector along the
/// leading dim (`dimensions={0}`, the per-row mask pattern — fusable as
/// a `Rep` leaf)?
pub fn rep_node(comp: &Computation, i: usize) -> bool {
    let ins = &comp.instrs[i];
    let Op::Broadcast { dims: map } = &ins.op else { return false };
    let Some((ty, od)) = arr_of(&ins.shape) else { return false };
    let Some(&o) = ins.operands.first() else { return false };
    let Some((oty, sd)) = arr_of(&comp.instrs[o].shape) else { return false };
    oty == ty
        && od.len() == 2
        && sd.len() == 1
        && map.len() == 1
        && map[0] == 0
        && sd[0] == od[0]
}

// --------------------------------------------------------------- compile

/// Compile the fused chain rooted at `root` (whose transitive operands
/// marked `inlined` fold into the kernel). Returns the kernel plus the
/// positions of the external operands, in kernel-input order.
///
/// `hots` names inlined *producer* nodes (`dot`/`gather`/`reduce`)
/// whose values the executing kernel supplies per block: recursion
/// stops there and a plain `Load` of that external input is emitted.
/// `lanes` is recorded as the kernel's lane width (the SIMD knob).
pub fn compile(
    comp: &Computation,
    root: usize,
    inlined: &[bool],
    hots: &[usize],
    lanes: u8,
) -> Result<(FusedKernel, Vec<usize>)> {
    let mut prog = Vec::new();
    let mut ops = Vec::new();
    let mut ext: Vec<usize> = Vec::new();
    let mut tys: Vec<Ty> = Vec::new();
    let (_, root_dims) = comp.instrs[root].shape.arr()?;
    let inner = if root_dims.len() == 2 { root_dims[1] } else { 0 };
    let mut cc = Emitter {
        comp,
        inlined,
        hots,
        inner,
        prog: &mut prog,
        ops: &mut ops,
        ext: &mut ext,
        tys: &mut tys,
    };
    cc.emit(root)?;
    if tys.len() != 1 {
        bail!("fused kernel left {} lanes on the stack", tys.len());
    }
    let (out_ty, _) = comp.instrs[root].shape.arr()?;
    if tys[0] != out_ty {
        bail!("fused kernel yields {:?}, root declares {:?}", tys[0], out_ty);
    }
    let uses_inner = prog.iter().any(|e| matches!(e, EInstr::Tile(_) | EInstr::Rep(_)));
    let k = FusedKernel {
        prog,
        n_inputs: ext.len(),
        out_ty,
        inner: if uses_inner { inner } else { 0 },
        lanes,
        ops,
    };
    Ok((k, ext))
}

struct Emitter<'a> {
    comp: &'a Computation,
    inlined: &'a [bool],
    hots: &'a [usize],
    inner: usize,
    prog: &'a mut Vec<EInstr>,
    ops: &'a mut Vec<&'static str>,
    ext: &'a mut Vec<usize>,
    tys: &'a mut Vec<Ty>,
}

impl Emitter<'_> {
    fn ext_index(&mut self, o: usize) -> u16 {
        match self.ext.iter().position(|&x| x == o) {
            Some(p) => p as u16,
            None => {
                self.ext.push(o);
                (self.ext.len() - 1) as u16
            }
        }
    }

    fn emit(&mut self, i: usize) -> Result<()> {
        let ins = &self.comp.instrs[i];
        let (out_ty, _) = ins.shape.arr()?;
        // Hot producer leaf: its block is supplied by the executing
        // kernel; emit a plain load of the external input.
        if self.hots.contains(&i) {
            let k = self.ext_index(i);
            self.prog.push(EInstr::Load(k));
            self.tys.push(out_ty);
            return Ok(());
        }
        // Broadcast leaf: push the broadcast's *operand* as a splat /
        // tile / rep read.
        if let Op::Broadcast { .. } = &ins.op {
            let o = ins.operands[0];
            let (sty, sdims) = self.comp.instrs[o].shape.arr()?;
            if sty != out_ty {
                bail!("fused broadcast type mismatch");
            }
            let k = self.ext_index(o);
            if sdims.iter().product::<usize>() == 1 {
                self.prog.push(EInstr::Splat(k));
            } else if tile_node(self.comp, i) && self.inner > 0 {
                self.prog.push(EInstr::Tile(k));
            } else if rep_node(self.comp, i) && self.inner > 0 {
                self.prog.push(EInstr::Rep(k));
            } else {
                bail!("broadcast {} is not a fusable leaf", ins.name);
            }
            self.tys.push(sty);
            self.ops.push("broadcast");
            return Ok(());
        }
        // Elementwise node: operands first (recursing into inlined ones),
        // then the op itself.
        for &o in &ins.operands {
            if self.inlined[o] {
                self.emit(o)?;
            } else {
                let (oty, _) = self.comp.instrs[o].shape.arr()?;
                let k = self.ext_index(o);
                self.prog.push(EInstr::Load(k));
                self.tys.push(oty);
            }
        }
        let pop =
            |tys: &mut Vec<Ty>| tys.pop().ok_or_else(|| anyhow!("stack underflow"));
        match &ins.op {
            Op::Binary(b) => {
                let tb = pop(self.tys)?;
                let ta = pop(self.tys)?;
                if ta != tb {
                    bail!("fused binary dtype mismatch");
                }
                match ta {
                    Ty::F32 => {
                        bin_f32(*b)?;
                    }
                    Ty::S32 => {
                        bin_i32(*b)?;
                    }
                    Ty::Pred => {
                        bin_pred(*b)?;
                    }
                }
                self.prog.push(EInstr::Bin(*b));
                self.tys.push(ta);
                self.ops.push(bin_name(*b));
            }
            Op::Unary(u) => {
                let ta = pop(self.tys)?;
                if !matches!((ta, u), (Ty::F32, _) | (Ty::S32, UnOp::Neg)) {
                    bail!("fused unary {u:?} on {}", ta.name());
                }
                self.prog.push(EInstr::Un(*u));
                self.tys.push(ta);
                self.ops.push(un_name(*u));
            }
            Op::Compare { dir } => {
                let tb = pop(self.tys)?;
                let ta = pop(self.tys)?;
                if ta != tb || ta == Ty::Pred {
                    bail!("fused compare dtype mismatch");
                }
                self.prog.push(EInstr::Cmp(*dir));
                self.tys.push(Ty::Pred);
                self.ops.push("compare");
            }
            Op::Select => {
                let tf = pop(self.tys)?;
                let tt = pop(self.tys)?;
                let tp = pop(self.tys)?;
                if tp != Ty::Pred || tt != tf {
                    bail!("fused select dtype mismatch");
                }
                self.prog.push(EInstr::Sel);
                self.tys.push(tt);
                self.ops.push("select");
            }
            Op::Convert => {
                let _ = pop(self.tys)?;
                if out_ty == Ty::Pred {
                    bail!("fused convert to pred");
                }
                self.prog.push(EInstr::Cvt(out_ty));
                self.tys.push(out_ty);
                self.ops.push("convert");
            }
            other => bail!("op {other:?} is not fusable"),
        }
        Ok(())
    }
}

fn bin_name(b: BinOp) -> &'static str {
    match b {
        BinOp::Add => "add",
        BinOp::Sub => "subtract",
        BinOp::Mul => "multiply",
        BinOp::Div => "divide",
        BinOp::Max => "maximum",
        BinOp::Min => "minimum",
        BinOp::And => "and",
        BinOp::Or => "or",
    }
}

fn un_name(u: UnOp) -> &'static str {
    match u {
        UnOp::Neg => "negate",
        UnOp::Tanh => "tanh",
        UnOp::Exp => "exponential",
        UnOp::Log => "log",
    }
}

// --------------------------------------------------------------- execute

/// One lane of the per-block evaluation stack.
pub enum Lane {
    F(Vec<f32>),
    I(Vec<i32>),
    P(Vec<bool>),
}

impl Lane {
    pub fn len(&self) -> usize {
        match self {
            Lane::F(v) => v.len(),
            Lane::I(v) => v.len(),
            Lane::P(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A borrowed block of values, indexed relative to the block start —
/// how calling kernels hand a fused chain its *hot* input (freshly
/// computed dot/gather rows, or the buffer being overwritten in place).
#[derive(Clone, Copy)]
pub enum BlockSlice<'a> {
    F(&'a [f32]),
    I(&'a [i32]),
    P(&'a [bool]),
}

impl BlockSlice<'_> {
    fn len(&self) -> usize {
        match self {
            BlockSlice::F(v) => v.len(),
            BlockSlice::I(v) => v.len(),
            BlockSlice::P(v) => v.len(),
        }
    }
}

/// Recycled lane buffers: after warm-up, block evaluation allocates
/// nothing. One scratch set serves a whole kernel invocation (or one
/// worker thread of it) across every block.
#[derive(Default)]
pub struct Scratch {
    f: Vec<Vec<f32>>,
    i: Vec<Vec<i32>>,
    p: Vec<Vec<bool>>,
    stack: Vec<Lane>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    fn take_f(&mut self) -> Vec<f32> {
        self.f.pop().unwrap_or_default()
    }
    fn take_i(&mut self) -> Vec<i32> {
        self.i.pop().unwrap_or_default()
    }
    fn take_p(&mut self) -> Vec<bool> {
        self.p.pop().unwrap_or_default()
    }

    /// Return a finished lane's buffer to the pool.
    pub fn recycle(&mut self, lane: Lane) {
        match lane {
            Lane::F(v) => self.f.push(v),
            Lane::I(v) => self.i.push(v),
            Lane::P(v) => self.p.push(v),
        }
    }

    /// Borrow a pooled `f32` buffer for caller-managed block temporaries
    /// (packed dot panels, hot row blocks); hand it back with
    /// [`Scratch::put_f`] so the capacity survives to the next call.
    pub fn lease_f(&mut self) -> Vec<f32> {
        self.f.pop().unwrap_or_default()
    }

    /// Return a buffer taken with [`Scratch::lease_f`] to the pool.
    pub fn put_f(&mut self, v: Vec<f32>) {
        self.f.push(v);
    }
}

thread_local! {
    static TL_SCRATCH: Cell<Option<Scratch>> = const { Cell::new(None) };
}

/// Run `f` with this thread's persistent [`Scratch`]: lane and block
/// buffers warmed up by one kernel invocation are reused by the next on
/// the same (pool worker) thread instead of reallocated per call. A
/// re-entrant call sees a fresh cold scratch rather than aliasing the
/// outer one; the outer scratch is checked back in when its call ends.
pub fn with_scratch<R>(f: impl FnOnce(&mut Scratch) -> R) -> R {
    TL_SCRATCH.with(|cell| {
        let mut s = cell.take().unwrap_or_default();
        let r = f(&mut s);
        cell.set(Some(s));
        r
    })
}

// ------------------------------------------------- vectorized lane kernels

/// `x[t] = f(x[t], y[t])` over [`LANES`]-wide fixed-size chunks with a
/// scalar remainder tail. The array views give the optimizer
/// straight-line 8-lane bodies to turn into SIMD; per element this is
/// the same function in the same operand order as the scalar loop, so
/// the result is bitwise identical.
#[inline]
fn vmap2<T: Copy, F: Fn(T, T) -> T>(x: &mut [T], y: &[T], f: F) {
    debug_assert_eq!(x.len(), y.len());
    let mut xc = x.chunks_exact_mut(LANES);
    let mut yc = y.chunks_exact(LANES);
    for (xa, ya) in (&mut xc).zip(&mut yc) {
        let a: &mut [T; LANES] = xa.try_into().expect("chunk width");
        let b: &[T; LANES] = ya.try_into().expect("chunk width");
        for l in 0..LANES {
            a[l] = f(a[l], b[l]);
        }
    }
    for (a, &b) in xc.into_remainder().iter_mut().zip(yc.remainder()) {
        *a = f(*a, b);
    }
}

/// `x[t] = f(x[t])` over [`LANES`]-wide chunks with a scalar tail.
#[inline]
fn vmap1<T: Copy, F: Fn(T) -> T>(x: &mut [T], f: F) {
    let mut xc = x.chunks_exact_mut(LANES);
    for xa in &mut xc {
        let a: &mut [T; LANES] = xa.try_into().expect("chunk width");
        for l in 0..LANES {
            a[l] = f(a[l]);
        }
    }
    for a in xc.into_remainder() {
        *a = f(*a);
    }
}

/// Per-opcode vectorized f32 binary kernels. Each arm monomorphizes
/// [`vmap2`] over the very expression `eval::bin_f32` applies, so the
/// chunked path cannot drift from the scalar table.
fn vbin_f32(op: BinOp, x: &mut [f32], y: &[f32]) -> Result<()> {
    match op {
        BinOp::Add => vmap2(x, y, |a, b| a + b),
        BinOp::Sub => vmap2(x, y, |a, b| a - b),
        BinOp::Mul => vmap2(x, y, |a, b| a * b),
        BinOp::Div => vmap2(x, y, |a, b| a / b),
        BinOp::Max => vmap2(x, y, f32::max),
        BinOp::Min => vmap2(x, y, f32::min),
        // Not defined on f32 — surface the scalar table's own error.
        BinOp::And | BinOp::Or => {
            bin_f32(op)?;
        }
    }
    Ok(())
}

/// Per-opcode vectorized i32 binary kernels (wrapping, like the scalar
/// table). `Div` keeps the exact scalar loop: its divide-by-zero guard
/// is a data-dependent branch the chunked body would only obscure.
fn vbin_i32(op: BinOp, x: &mut [i32], y: &[i32]) -> Result<()> {
    match op {
        BinOp::Add => vmap2(x, y, |a, b| a.wrapping_add(b)),
        BinOp::Sub => vmap2(x, y, |a, b| a.wrapping_sub(b)),
        BinOp::Mul => vmap2(x, y, |a, b| a.wrapping_mul(b)),
        BinOp::Max => vmap2(x, y, i32::max),
        BinOp::Min => vmap2(x, y, i32::min),
        BinOp::Div => {
            let f = bin_i32(op)?;
            for (a, &b) in x.iter_mut().zip(y) {
                *a = f(*a, b);
            }
        }
        BinOp::And | BinOp::Or => {
            bin_i32(op)?;
        }
    }
    Ok(())
}

/// Vectorized f32 unary kernels. The transcendentals stay on the scalar
/// table — they call libm either way, and keeping one source means the
/// SIMD knob cannot change a single bit of their output.
fn vun_f32(op: UnOp, x: &mut [f32]) {
    match op {
        UnOp::Neg => vmap1(x, |a| -a),
        UnOp::Tanh | UnOp::Exp | UnOp::Log => {
            let f = un_f32(op);
            for v in x.iter_mut() {
                *v = f(*v);
            }
        }
    }
}

#[derive(Clone, Copy)]
enum Scalar {
    F(f32),
    I(i32),
    P(bool),
}

/// How a kernel input is referenced by the bytecode (derived from the
/// program at context build time; drives size validation).
#[derive(Clone, Copy, PartialEq, Debug)]
enum Role {
    Unused,
    Load,
    Splat,
    Tile,
    Rep,
}

/// A prepared fused-kernel evaluation: validated inputs, pre-read splat
/// scalars, optional hot input. Holds only shared references — safe to
/// share across pool threads, each with its own [`Scratch`]. This `Sync`
/// bound is load-bearing twice over: row-blocked kernels share one ctx
/// across `scope_run` tasks, and the plan scheduler ([`super::sched`])
/// additionally runs whole fused steps *on* pool workers, so a ctx may
/// be built and consumed entirely off the dispatching thread.
pub struct FusedCtx<'k, 't> {
    k: &'k FusedKernel,
    inputs: Vec<Option<&'t Tensor>>,
    scalars: Vec<Option<Scalar>>,
    /// Kernel-input positions supplied per block by the caller, sorted
    /// ascending; `eval_block`'s hot slices are indexed by position here.
    hots: Vec<u16>,
    n: usize,
}

// Compile-time proof of the sharing contract above: a ctx crossing onto
// scheduler/pool worker threads must stay `Sync` (and `Send`, for the
// build-off-thread case) no matter what fields grow here later.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<FusedCtx<'static, 'static>>();
};

impl<'k, 't> FusedCtx<'k, 't> {
    /// Validate `inputs` (one per kernel input; `None` only at the `hots`
    /// positions) against the kernel's roles for a virtual element count
    /// of `n`.
    pub fn new(
        k: &'k FusedKernel,
        inputs: Vec<Option<&'t Tensor>>,
        n: usize,
        hots: &[u16],
    ) -> Result<FusedCtx<'k, 't>> {
        if inputs.len() != k.n_inputs {
            bail!("fused kernel wants {} inputs, got {}", k.n_inputs, inputs.len());
        }
        let mut roles = vec![Role::Unused; k.n_inputs];
        let mut set = |i: u16, r: Role| -> Result<()> {
            let slot = &mut roles[i as usize];
            if *slot != Role::Unused && *slot != r {
                bail!("fused input {i} used as both {:?} and {r:?}", *slot);
            }
            *slot = r;
            Ok(())
        };
        for e in &k.prog {
            match e {
                EInstr::Load(i) => set(*i, Role::Load)?,
                EInstr::Splat(i) => set(*i, Role::Splat)?,
                EInstr::Tile(i) => set(*i, Role::Tile)?,
                EInstr::Rep(i) => set(*i, Role::Rep)?,
                _ => {}
            }
        }
        let mut hots = hots.to_vec();
        hots.sort_unstable();
        hots.dedup();
        let mut scalars: Vec<Option<Scalar>> = vec![None; k.n_inputs];
        for (i, t) in inputs.iter().enumerate() {
            if hots.contains(&(i as u16)) {
                if roles[i] != Role::Load {
                    bail!("fused hot input {i} must be a plain load");
                }
                continue;
            }
            let Some(t) = t else { bail!("fused input {i} missing") };
            let want = match roles[i] {
                Role::Unused => continue,
                Role::Load => n,
                Role::Splat => 1,
                Role::Tile => {
                    if k.inner == 0 {
                        bail!("fused tile input without an inner period");
                    }
                    k.inner
                }
                Role::Rep => {
                    if k.inner == 0 || n % k.inner != 0 {
                        bail!("fused rep input without a whole inner period");
                    }
                    n / k.inner
                }
            };
            if t.elements() != want {
                bail!("fused input {i}: {} elements, want {want}", t.elements());
            }
            if roles[i] == Role::Splat {
                scalars[i] = Some(match &t.data {
                    Data::F32(v) => Scalar::F(v[0]),
                    Data::I32(v) => Scalar::I(v[0]),
                    Data::Pred(v) => Scalar::P(v[0]),
                });
            }
        }
        Ok(FusedCtx { k, inputs, scalars, hots, n })
    }

    pub fn out_ty(&self) -> Ty {
        self.k.out_ty
    }

    pub fn elements(&self) -> usize {
        self.n
    }

    /// Evaluate elements `[lo, hi)` of the chain, reading the hot inputs
    /// from `hots` (one block per hot position, in the ctx's sorted hot
    /// order, each indexed relative to `lo`). The result lane holds
    /// `hi - lo` elements; recycle it via [`Scratch::recycle`].
    pub fn eval_block(
        &self,
        lo: usize,
        hi: usize,
        hots: &[BlockSlice],
        s: &mut Scratch,
    ) -> Result<Lane> {
        if hi > self.n || lo > hi {
            bail!("fused block [{lo}, {hi}) out of range 0..{}", self.n);
        }
        if hots.len() != self.hots.len() {
            bail!("fused: {} hot blocks for {} hot inputs", hots.len(), self.hots.len());
        }
        for b in hots {
            if b.len() != hi - lo {
                bail!("fused: hot block has {} elements, want {}", b.len(), hi - lo);
            }
        }
        for e in &self.k.prog {
            self.step(e, lo, hi, hots, s)?;
        }
        let r = s.stack.pop().ok_or_else(|| anyhow!("fused: empty result stack"))?;
        if !s.stack.is_empty() {
            bail!("fused: {} stray lanes after block", s.stack.len());
        }
        Ok(r)
    }

    fn input(&self, i: u16) -> Result<&'t Tensor> {
        self.inputs[i as usize]
            .ok_or_else(|| anyhow!("fused: input {i} has no tensor backing"))
    }

    fn step(
        &self,
        e: &EInstr,
        lo: usize,
        hi: usize,
        hots: &[BlockSlice],
        s: &mut Scratch,
    ) -> Result<()> {
        let len = hi - lo;
        match e {
            EInstr::Load(i) => {
                if let Some(j) = self.hots.iter().position(|h| h == i) {
                    let lane = match hots[j] {
                        BlockSlice::F(v) => {
                            let mut b = s.take_f();
                            b.clear();
                            b.extend_from_slice(v);
                            Lane::F(b)
                        }
                        BlockSlice::I(v) => {
                            let mut b = s.take_i();
                            b.clear();
                            b.extend_from_slice(v);
                            Lane::I(b)
                        }
                        BlockSlice::P(v) => {
                            let mut b = s.take_p();
                            b.clear();
                            b.extend_from_slice(v);
                            Lane::P(b)
                        }
                    };
                    s.stack.push(lane);
                    return Ok(());
                }
                let lane = match &self.input(*i)?.data {
                    Data::F32(v) => {
                        let mut b = s.take_f();
                        b.clear();
                        b.extend_from_slice(&v[lo..hi]);
                        Lane::F(b)
                    }
                    Data::I32(v) => {
                        let mut b = s.take_i();
                        b.clear();
                        b.extend_from_slice(&v[lo..hi]);
                        Lane::I(b)
                    }
                    Data::Pred(v) => {
                        let mut b = s.take_p();
                        b.clear();
                        b.extend_from_slice(&v[lo..hi]);
                        Lane::P(b)
                    }
                };
                s.stack.push(lane);
            }
            EInstr::Splat(i) => {
                let lane = match self.scalars[*i as usize] {
                    Some(Scalar::F(x)) => {
                        let mut b = s.take_f();
                        b.clear();
                        b.resize(len, x);
                        Lane::F(b)
                    }
                    Some(Scalar::I(x)) => {
                        let mut b = s.take_i();
                        b.clear();
                        b.resize(len, x);
                        Lane::I(b)
                    }
                    Some(Scalar::P(x)) => {
                        let mut b = s.take_p();
                        b.clear();
                        b.resize(len, x);
                        Lane::P(b)
                    }
                    None => bail!("fused: splat input {i} missing scalar"),
                };
                s.stack.push(lane);
            }
            EInstr::Tile(i) => {
                let inner = self.k.inner;
                let lane = match &self.input(*i)?.data {
                    Data::F32(v) => {
                        let mut b = s.take_f();
                        fill_tile(v, lo, len, inner, &mut b);
                        Lane::F(b)
                    }
                    Data::I32(v) => {
                        let mut b = s.take_i();
                        fill_tile(v, lo, len, inner, &mut b);
                        Lane::I(b)
                    }
                    Data::Pred(v) => {
                        let mut b = s.take_p();
                        fill_tile(v, lo, len, inner, &mut b);
                        Lane::P(b)
                    }
                };
                s.stack.push(lane);
            }
            EInstr::Rep(i) => {
                let inner = self.k.inner;
                let lane = match &self.input(*i)?.data {
                    Data::F32(v) => {
                        let mut b = s.take_f();
                        fill_rep(v, lo, hi, inner, &mut b);
                        Lane::F(b)
                    }
                    Data::I32(v) => {
                        let mut b = s.take_i();
                        fill_rep(v, lo, hi, inner, &mut b);
                        Lane::I(b)
                    }
                    Data::Pred(v) => {
                        let mut b = s.take_p();
                        fill_rep(v, lo, hi, inner, &mut b);
                        Lane::P(b)
                    }
                };
                s.stack.push(lane);
            }
            EInstr::Bin(op) => {
                let b = s.stack.pop().ok_or_else(|| anyhow!("fused: bin underflow"))?;
                let a =
                    s.stack.last_mut().ok_or_else(|| anyhow!("fused: bin underflow"))?;
                let wide = self.k.lanes as usize >= LANES;
                match (a, &b) {
                    (Lane::F(x), Lane::F(y)) => {
                        if wide {
                            vbin_f32(*op, x, y)?;
                        } else {
                            let f = bin_f32(*op)?;
                            for (xa, &yb) in x.iter_mut().zip(y.iter()) {
                                *xa = f(*xa, yb);
                            }
                        }
                    }
                    (Lane::I(x), Lane::I(y)) => {
                        if wide {
                            vbin_i32(*op, x, y)?;
                        } else {
                            let f = bin_i32(*op)?;
                            for (xa, &yb) in x.iter_mut().zip(y.iter()) {
                                *xa = f(*xa, yb);
                            }
                        }
                    }
                    (Lane::P(x), Lane::P(y)) => {
                        let f = bin_pred(*op)?;
                        for (xa, &yb) in x.iter_mut().zip(y.iter()) {
                            *xa = f(*xa, yb);
                        }
                    }
                    _ => bail!("fused: bin lane type mismatch"),
                }
                s.recycle(b);
            }
            EInstr::Cmp(dir) => {
                let b = s.stack.pop().ok_or_else(|| anyhow!("fused: cmp underflow"))?;
                let a = s.stack.pop().ok_or_else(|| anyhow!("fused: cmp underflow"))?;
                let mut out = s.take_p();
                out.clear();
                fn cmp<T: PartialOrd + Copy>(
                    dir: CmpDir,
                    a: &[T],
                    b: &[T],
                    out: &mut Vec<bool>,
                ) {
                    let f = eval::cmp_of::<T>(dir);
                    out.extend(a.iter().zip(b).map(|(&x, &y)| f(x, y)));
                }
                match (&a, &b) {
                    (Lane::F(x), Lane::F(y)) => cmp(*dir, x, y, &mut out),
                    (Lane::I(x), Lane::I(y)) => cmp(*dir, x, y, &mut out),
                    _ => bail!("fused: cmp lane type mismatch"),
                }
                s.stack.push(Lane::P(out));
                s.recycle(a);
                s.recycle(b);
            }
            EInstr::Sel => {
                let f = s.stack.pop().ok_or_else(|| anyhow!("fused: sel underflow"))?;
                let mut t = s.stack.pop().ok_or_else(|| anyhow!("fused: sel underflow"))?;
                let p = s.stack.pop().ok_or_else(|| anyhow!("fused: sel underflow"))?;
                let Lane::P(pv) = &p else { bail!("fused: sel pred lane") };
                match (&mut t, &f) {
                    (Lane::F(tv), Lane::F(fv)) => {
                        for ((tx, &fx), &c) in tv.iter_mut().zip(fv.iter()).zip(pv.iter()) {
                            if !c {
                                *tx = fx;
                            }
                        }
                    }
                    (Lane::I(tv), Lane::I(fv)) => {
                        for ((tx, &fx), &c) in tv.iter_mut().zip(fv.iter()).zip(pv.iter()) {
                            if !c {
                                *tx = fx;
                            }
                        }
                    }
                    (Lane::P(tv), Lane::P(fv)) => {
                        for ((tx, &fx), &c) in tv.iter_mut().zip(fv.iter()).zip(pv.iter()) {
                            if !c {
                                *tx = fx;
                            }
                        }
                    }
                    _ => bail!("fused: sel lane type mismatch"),
                }
                s.stack.push(t);
                s.recycle(p);
                s.recycle(f);
            }
            EInstr::Un(op) => {
                let a =
                    s.stack.last_mut().ok_or_else(|| anyhow!("fused: un underflow"))?;
                match (a, op) {
                    (Lane::F(x), _) => {
                        if self.k.lanes as usize >= LANES {
                            vun_f32(*op, x);
                        } else {
                            let f = un_f32(*op);
                            for v in x.iter_mut() {
                                *v = f(*v);
                            }
                        }
                    }
                    (Lane::I(x), UnOp::Neg) => {
                        for v in x.iter_mut() {
                            *v = v.wrapping_neg();
                        }
                    }
                    _ => bail!("fused: unary lane type mismatch"),
                }
            }
            EInstr::Cvt(ty) => {
                use super::eval::{cast_f32_i32, cast_i32_f32, cast_pred_f32, cast_pred_i32};
                let a = s.stack.pop().ok_or_else(|| anyhow!("fused: cvt underflow"))?;
                let lane = match (a, ty) {
                    (Lane::F(x), Ty::F32) => Lane::F(x),
                    (Lane::I(x), Ty::S32) => Lane::I(x),
                    (a, Ty::F32) => {
                        let mut out = s.take_f();
                        out.clear();
                        match &a {
                            Lane::I(x) => out.extend(x.iter().map(|&v| cast_i32_f32(v))),
                            Lane::P(x) => out.extend(x.iter().map(|&b| cast_pred_f32(b))),
                            Lane::F(_) => unreachable!(),
                        }
                        s.recycle(a);
                        Lane::F(out)
                    }
                    (a, Ty::S32) => {
                        let mut out = s.take_i();
                        out.clear();
                        match &a {
                            Lane::F(x) => out.extend(x.iter().map(|&v| cast_f32_i32(v))),
                            Lane::P(x) => out.extend(x.iter().map(|&b| cast_pred_i32(b))),
                            Lane::I(_) => unreachable!(),
                        }
                        s.recycle(a);
                        Lane::I(out)
                    }
                    (_, Ty::Pred) => bail!("fused: convert to pred"),
                };
                s.stack.push(lane);
            }
        }
        Ok(())
    }
}

/// `out[t] = src[(lo + t) % inner]` for `t in 0..len`, filled in
/// contiguous runs.
fn fill_tile<T: Copy>(src: &[T], lo: usize, len: usize, inner: usize, out: &mut Vec<T>) {
    out.clear();
    let mut cur = lo % inner;
    let mut filled = 0usize;
    while filled < len {
        let take = (inner - cur).min(len - filled);
        out.extend_from_slice(&src[cur..cur + take]);
        filled += take;
        cur = (cur + take) % inner;
    }
}

/// `out[t] = src[(lo + t) / inner]` for `lo + t in [lo, hi)`, filled in
/// per-row runs.
fn fill_rep<T: Copy>(src: &[T], lo: usize, hi: usize, inner: usize, out: &mut Vec<T>) {
    out.clear();
    let mut pos = lo;
    while pos < hi {
        let r = pos / inner;
        let run_end = ((r + 1) * inner).min(hi);
        out.resize(out.len() + (run_end - pos), src[r]);
        pos = run_end;
    }
}

// ---------------------------------------------------- whole-tensor drivers

/// Execute a fused kernel over `inputs`, producing the `out_dims` tensor.
pub fn run_fused(k: &FusedKernel, inputs: &[&Tensor], out_dims: &[usize]) -> Result<Tensor> {
    let n: usize = out_dims.iter().product();
    if let Some(t) = fast_single_op(k, inputs, out_dims)? {
        return Ok(t);
    }
    let ctx = FusedCtx::new(k, inputs.iter().map(|t| Some(*t)).collect(), n, &[])?;
    let mut sink = OutSink::new(k.out_ty, n);
    with_scratch(|s| -> Result<()> {
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + BLOCK).min(n);
            let lane = ctx.eval_block(lo, hi, &[], s)?;
            sink.push(&lane)?;
            s.recycle(lane);
            lo = hi;
        }
        Ok(())
    })?;
    sink.finish(out_dims)
}

/// Does this tensor own its storage uniquely (safe to overwrite)?
pub fn unique_storage(t: &Tensor) -> bool {
    match &t.data {
        Data::F32(a) => std::sync::Arc::strong_count(a) == 1,
        Data::I32(a) => std::sync::Arc::strong_count(a) == 1,
        Data::Pred(a) => std::sync::Arc::strong_count(a) == 1,
    }
}

/// Execute a fused kernel writing the output **into** `reuse` — a dying,
/// uniquely-owned input (kernel position `pos`, `inputs[pos]` must be
/// `None`) whose element count and dtype match the output. Each block is
/// read before it is overwritten and later blocks never read earlier
/// elements, so the result is bitwise identical to [`run_fused`].
pub fn run_fused_in_place(
    k: &FusedKernel,
    inputs: Vec<Option<&Tensor>>,
    pos: u16,
    reuse: Tensor,
    out_dims: &[usize],
) -> Result<Tensor> {
    let n: usize = out_dims.iter().product();
    if reuse.elements() != n || reuse.data.ty() != k.out_ty {
        bail!("fused in-place reuse: size or dtype mismatch");
    }
    let ctx = FusedCtx::new(k, inputs, n, &[pos])?;
    match reuse.data {
        Data::F32(arc) => {
            let mut buf = std::sync::Arc::try_unwrap(arc)
                .map_err(|_| anyhow!("fused in-place reuse of shared storage"))?;
            with_scratch(|s| -> Result<()> {
                let mut lo = 0usize;
                while lo < n {
                    let hi = (lo + BLOCK).min(n);
                    let lane = ctx.eval_block(lo, hi, &[BlockSlice::F(&buf[lo..hi])], s)?;
                    let Lane::F(v) = &lane else { bail!("fused in-place: lane type") };
                    buf[lo..hi].copy_from_slice(v);
                    s.recycle(lane);
                    lo = hi;
                }
                Ok(())
            })?;
            Ok(Tensor::f32(buf, out_dims.to_vec()))
        }
        Data::I32(arc) => {
            let mut buf = std::sync::Arc::try_unwrap(arc)
                .map_err(|_| anyhow!("fused in-place reuse of shared storage"))?;
            with_scratch(|s| -> Result<()> {
                let mut lo = 0usize;
                while lo < n {
                    let hi = (lo + BLOCK).min(n);
                    let lane = ctx.eval_block(lo, hi, &[BlockSlice::I(&buf[lo..hi])], s)?;
                    let Lane::I(v) = &lane else { bail!("fused in-place: lane type") };
                    buf[lo..hi].copy_from_slice(v);
                    s.recycle(lane);
                    lo = hi;
                }
                Ok(())
            })?;
            Ok(Tensor::i32(buf, out_dims.to_vec()))
        }
        Data::Pred(arc) => {
            let mut buf = std::sync::Arc::try_unwrap(arc)
                .map_err(|_| anyhow!("fused in-place reuse of shared storage"))?;
            with_scratch(|s| -> Result<()> {
                let mut lo = 0usize;
                while lo < n {
                    let hi = (lo + BLOCK).min(n);
                    let lane = ctx.eval_block(lo, hi, &[BlockSlice::P(&buf[lo..hi])], s)?;
                    let Lane::P(v) = &lane else { bail!("fused in-place: lane type") };
                    buf[lo..hi].copy_from_slice(v);
                    s.recycle(lane);
                    lo = hi;
                }
                Ok(())
            })?;
            Ok(Tensor::pred(buf, out_dims.to_vec()))
        }
    }
}

/// Typed output accumulator for blocked execution.
pub struct OutSink {
    ty: Ty,
    f: Vec<f32>,
    i: Vec<i32>,
    p: Vec<bool>,
}

impl OutSink {
    pub fn new(ty: Ty, n: usize) -> OutSink {
        let mut s = OutSink { ty, f: Vec::new(), i: Vec::new(), p: Vec::new() };
        match ty {
            Ty::F32 => s.f.reserve_exact(n),
            Ty::S32 => s.i.reserve_exact(n),
            Ty::Pred => s.p.reserve_exact(n),
        }
        s
    }

    pub fn push(&mut self, lane: &Lane) -> Result<()> {
        match (lane, self.ty) {
            (Lane::F(v), Ty::F32) => self.f.extend_from_slice(v),
            (Lane::I(v), Ty::S32) => self.i.extend_from_slice(v),
            (Lane::P(v), Ty::Pred) => self.p.extend_from_slice(v),
            _ => bail!("fused: result lane type mismatch"),
        }
        Ok(())
    }

    pub fn finish(self, out_dims: &[usize]) -> Result<Tensor> {
        Ok(match self.ty {
            Ty::F32 => Tensor::f32(self.f, out_dims.to_vec()),
            Ty::S32 => Tensor::i32(self.i, out_dims.to_vec()),
            Ty::Pred => Tensor::pred(self.p, out_dims.to_vec()),
        })
    }
}

/// Whole-tensor fast path for one-op kernels (a single fused instruction
/// over direct loads / one splat): skips the block loop and lane copies
/// entirely. Returns `Ok(None)` when the program shape doesn't match —
/// the generic path then handles it (including its error reporting).
fn fast_single_op(
    k: &FusedKernel,
    inputs: &[&Tensor],
    out_dims: &[usize],
) -> Result<Option<Tensor>> {
    if inputs.len() != k.n_inputs {
        return Ok(None);
    }
    let n: usize = out_dims.iter().product();
    // Any size precondition miss falls through to the generic path,
    // which owns the error reporting.
    let load = |i: &u16| inputs.get(*i as usize).copied().filter(|t| t.elements() == n);
    let reshaped = |mut t: Tensor| {
        t.dims = out_dims.to_vec();
        t
    };
    match k.prog.as_slice() {
        [EInstr::Load(a), EInstr::Un(u)] => {
            let Some(ta) = load(a) else { return Ok(None) };
            Ok(Some(reshaped(eval::unary(*u, ta)?)))
        }
        [EInstr::Load(a), EInstr::Load(b), EInstr::Bin(op)] => {
            let (Some(ta), Some(tb)) = (load(a), load(b)) else { return Ok(None) };
            Ok(Some(reshaped(eval::binary(*op, ta, tb)?)))
        }
        [EInstr::Load(a), EInstr::Splat(sc), EInstr::Bin(op)] => {
            let (Some(ta), Some(ts)) = (load(a), inputs.get(*sc as usize).copied()) else {
                return Ok(None);
            };
            scalar_bin(*op, ta, ts, false, out_dims)
        }
        [EInstr::Splat(sc), EInstr::Load(a), EInstr::Bin(op)] => {
            let (Some(ta), Some(ts)) = (load(a), inputs.get(*sc as usize).copied()) else {
                return Ok(None);
            };
            scalar_bin(*op, ta, ts, true, out_dims)
        }
        _ => Ok(None),
    }
}

/// `f(x, s)` (or `f(s, x)` when `scalar_first`) over a whole tensor —
/// the same scalar functions the bytecode applies, in the same operand
/// order, so results are bitwise identical to the blocked path.
fn scalar_bin(
    op: BinOp,
    x: &Tensor,
    scalar: &Tensor,
    scalar_first: bool,
    out_dims: &[usize],
) -> Result<Option<Tensor>> {
    if scalar.elements() != 1 {
        return Ok(None);
    }
    let dims = out_dims.to_vec();
    Ok(Some(match (&x.data, &scalar.data) {
        (Data::F32(v), Data::F32(sv)) => {
            let f = bin_f32(op)?;
            let s = sv[0];
            let out: Vec<f32> = if scalar_first {
                v.iter().map(|&a| f(s, a)).collect()
            } else {
                v.iter().map(|&a| f(a, s)).collect()
            };
            Tensor::f32(out, dims)
        }
        (Data::I32(v), Data::I32(sv)) => {
            let f = bin_i32(op)?;
            let s = sv[0];
            let out: Vec<i32> = if scalar_first {
                v.iter().map(|&a| f(s, a)).collect()
            } else {
                v.iter().map(|&a| f(a, s)).collect()
            };
            Tensor::i32(out, dims)
        }
        (Data::Pred(v), Data::Pred(sv)) => {
            let f = bin_pred(op)?;
            let s = sv[0];
            let out: Vec<bool> = if scalar_first {
                v.iter().map(|&a| f(s, a)).collect()
            } else {
                v.iter().map(|&a| f(a, s)).collect()
            };
            Tensor::pred(out, dims)
        }
        _ => return Ok(None),
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f32s(n: usize, seed: f32) -> Vec<f32> {
        (0..n).map(|i| (i as f32 * 0.37 + seed).sin()).collect()
    }

    fn kernel(prog: Vec<EInstr>, n_inputs: usize, out_ty: Ty, inner: usize) -> FusedKernel {
        FusedKernel { prog, n_inputs, out_ty, inner, lanes: LANES as u8, ops: vec![] }
    }

    #[test]
    fn hand_built_kernel_matches_scalar_reference_across_blocks() {
        // out = (-(a + b)) * a, over more than one block.
        let n = BLOCK * 2 + 177;
        let a = f32s(n, 0.1);
        let b = f32s(n, 2.5);
        let k = kernel(
            vec![
                EInstr::Load(0),
                EInstr::Load(1),
                EInstr::Bin(BinOp::Add),
                EInstr::Un(UnOp::Neg),
                EInstr::Load(0),
                EInstr::Bin(BinOp::Mul),
            ],
            2,
            Ty::F32,
            0,
        );
        let ta = Tensor::f32(a.clone(), vec![n]);
        let tb = Tensor::f32(b.clone(), vec![n]);
        let out = run_fused(&k, &[&ta, &tb], &[n]).unwrap();
        for ((&o, &x), &y) in out.f().unwrap().iter().zip(&a).zip(&b) {
            assert_eq!(o, -(x + y) * x);
        }
    }

    #[test]
    fn splat_compare_select_convert_chain() {
        // out_f32 = convert_s32(select(i < 0, splat(100), i))
        let n = BLOCK + 5;
        let iv: Vec<i32> = (0..n as i32).map(|i| i - 600).collect();
        let k = kernel(
            vec![
                EInstr::Load(0),
                EInstr::Splat(1),
                EInstr::Cmp(CmpDir::Lt),
                EInstr::Splat(2),
                EInstr::Load(0),
                EInstr::Sel,
                EInstr::Cvt(Ty::F32),
            ],
            3,
            Ty::F32,
            0,
        );
        let ti = Tensor::i32(iv.clone(), vec![n]);
        let zero = Tensor::i32(vec![0], vec![]);
        let hundred = Tensor::i32(vec![100], vec![]);
        let out = run_fused(&k, &[&ti, &zero, &hundred], &[n]).unwrap();
        for (&o, &i) in out.f().unwrap().iter().zip(&iv) {
            let want = if i < 0 { 100.0 } else { i as f32 };
            assert_eq!(o, want);
        }
    }

    #[test]
    fn input_size_validation() {
        let k = kernel(vec![EInstr::Load(0), EInstr::Un(UnOp::Neg)], 1, Ty::F32, 0);
        let wrong = Tensor::f32(vec![1.0, 2.0], vec![2]);
        assert!(run_fused(&k, &[&wrong], &[3]).is_err());
        let empty = Tensor::f32(vec![], vec![0]);
        let out = run_fused(&k, &[&empty], &[0]).unwrap();
        assert_eq!(out.elements(), 0);
    }

    #[test]
    fn tile_and_rep_leaves_match_broadcast_semantics() {
        // out[r, j] = (x[r, j] + bias[j]) * mask_as_f32... keep it f32:
        // out = x + tile(bias) + rep(col)
        let (m, inner) = (7usize, 5usize);
        let n = m * inner;
        let x = f32s(n, 0.3);
        let bias = f32s(inner, 1.1);
        let col = f32s(m, 2.2);
        let k = kernel(
            vec![
                EInstr::Load(0),
                EInstr::Tile(1),
                EInstr::Bin(BinOp::Add),
                EInstr::Rep(2),
                EInstr::Bin(BinOp::Add),
            ],
            3,
            Ty::F32,
            inner,
        );
        let tx = Tensor::f32(x.clone(), vec![m, inner]);
        let tb = Tensor::f32(bias.clone(), vec![inner]);
        let tc = Tensor::f32(col.clone(), vec![m]);
        let out = run_fused(&k, &[&tx, &tb, &tc], &[m, inner]).unwrap();
        for r in 0..m {
            for j in 0..inner {
                assert_eq!(out.f().unwrap()[r * inner + j], x[r * inner + j] + bias[j] + col[r]);
            }
        }
        // The modular index math must hold at arbitrary (non-row-aligned)
        // block offsets too: evaluate an unaligned sub-range directly.
        let ctx = FusedCtx::new(&k, vec![Some(&tx), Some(&tb), Some(&tc)], n, &[]).unwrap();
        let mut s = Scratch::new();
        let (lo, hi) = (3usize, n - 2);
        let lane = ctx.eval_block(lo, hi, &[], &mut s).unwrap();
        let Lane::F(v) = &lane else { panic!("lane type") };
        for (t, &got) in v.iter().enumerate() {
            let i = lo + t;
            assert_eq!(got, x[i] + bias[i % inner] + col[i / inner]);
        }
    }

    #[test]
    fn hot_block_feeds_the_marked_input() {
        // out = hot + c, where the hot input is supplied per block.
        let n = 10usize;
        let c = f32s(n, 0.9);
        let k = kernel(
            vec![EInstr::Load(0), EInstr::Load(1), EInstr::Bin(BinOp::Add)],
            2,
            Ty::F32,
            0,
        );
        let tc = Tensor::f32(c.clone(), vec![n]);
        let ctx = FusedCtx::new(&k, vec![None, Some(&tc)], n, &[0]).unwrap();
        let mut s = Scratch::new();
        let hot: Vec<f32> = (0..4).map(|i| i as f32).collect();
        let lane = ctx.eval_block(2, 6, &[BlockSlice::F(&hot)], &mut s).unwrap();
        let Lane::F(v) = &lane else { panic!("lane type") };
        for t in 0..4 {
            assert_eq!(v[t], hot[t] + c[2 + t]);
        }
        // A missing hot block is an error, not a silent misread.
        assert!(ctx.eval_block(2, 6, &[], &mut s).is_err());
    }

    #[test]
    fn multi_hot_blocks_feed_the_marked_inputs() {
        // out = h0 - h1 + c, with two hot inputs supplied per block.
        let n = 12usize;
        let c = f32s(n, 0.7);
        let k = kernel(
            vec![
                EInstr::Load(0),
                EInstr::Load(1),
                EInstr::Bin(BinOp::Sub),
                EInstr::Load(2),
                EInstr::Bin(BinOp::Add),
            ],
            3,
            Ty::F32,
            0,
        );
        let tc = Tensor::f32(c.clone(), vec![n]);
        let ctx = FusedCtx::new(&k, vec![None, None, Some(&tc)], n, &[0, 1]).unwrap();
        let mut s = Scratch::new();
        let h0: Vec<f32> = (0..5).map(|i| 10.0 + i as f32).collect();
        let h1: Vec<f32> = (0..5).map(|i| 0.5 * i as f32).collect();
        let lane = ctx
            .eval_block(4, 9, &[BlockSlice::F(&h0), BlockSlice::F(&h1)], &mut s)
            .unwrap();
        let Lane::F(v) = &lane else { panic!("lane type") };
        for t in 0..5 {
            assert_eq!(v[t], h0[t] - h1[t] + c[4 + t]);
        }
        // Wrong hot-block count is an error, not a silent misread.
        assert!(ctx.eval_block(4, 9, &[BlockSlice::F(&h0)], &mut s).is_err());
    }

    #[test]
    fn vector_lanes_match_scalar_lanes_bitwise_with_tail() {
        // n deliberately not a multiple of LANES: chunked body + tail.
        let n = LANES * 5 + 3;
        let a = f32s(n, 0.4);
        let b: Vec<f32> = f32s(n, 3.3).iter().map(|v| v + 1.5).collect();
        let prog = vec![
            EInstr::Load(0),
            EInstr::Load(1),
            EInstr::Bin(BinOp::Max),
            EInstr::Un(UnOp::Neg),
            EInstr::Load(1),
            EInstr::Bin(BinOp::Div),
        ];
        let ta = Tensor::f32(a.clone(), vec![n]);
        let tb = Tensor::f32(b.clone(), vec![n]);
        let wide = kernel(prog.clone(), 2, Ty::F32, 0);
        let mut narrow = kernel(prog, 2, Ty::F32, 0);
        narrow.lanes = 1;
        let got = run_fused(&wide, &[&ta, &tb], &[n]).unwrap();
        let want = run_fused(&narrow, &[&ta, &tb], &[n]).unwrap();
        assert_eq!(got.f().unwrap(), want.f().unwrap());
        for ((&o, &x), &y) in got.f().unwrap().iter().zip(&a).zip(&b) {
            assert_eq!(o, -(x.max(y)) / y);
        }
    }

    #[test]
    fn vector_i32_lanes_match_scalar_wrapping() {
        let n = 29usize; // 3 chunks + 5-element tail
        let a: Vec<i32> = (0..n as i32).map(|i| i.wrapping_mul(0x7ead_beef)).collect();
        let b: Vec<i32> = (0..n as i32).map(|i| i.wrapping_mul(0x1234_5677).wrapping_add(7)).collect();
        let prog = vec![
            EInstr::Load(0),
            EInstr::Load(1),
            EInstr::Bin(BinOp::Mul),
            EInstr::Load(1),
            EInstr::Bin(BinOp::Add),
        ];
        let ta = Tensor::i32(a.clone(), vec![n]);
        let tb = Tensor::i32(b.clone(), vec![n]);
        let wide = kernel(prog.clone(), 2, Ty::S32, 0);
        let mut narrow = kernel(prog, 2, Ty::S32, 0);
        narrow.lanes = 1;
        let got = run_fused(&wide, &[&ta, &tb], &[n]).unwrap();
        let want = run_fused(&narrow, &[&ta, &tb], &[n]).unwrap();
        assert_eq!(got.i().unwrap(), want.i().unwrap());
        for ((&o, &x), &y) in got.i().unwrap().iter().zip(&a).zip(&b) {
            assert_eq!(o, x.wrapping_mul(y).wrapping_add(y));
        }
    }

    #[test]
    fn tile_rep_periods_straddling_chunks_match_scalar() {
        // inner = 5 is coprime with LANES = 8, so every chunk crosses a
        // tile/rep period boundary somewhere.
        let (m, inner) = (9usize, 5usize);
        let n = m * inner;
        let x = f32s(n, 0.6);
        let bias = f32s(inner, 1.9);
        let col = f32s(m, 2.8);
        let prog = vec![
            EInstr::Load(0),
            EInstr::Tile(1),
            EInstr::Bin(BinOp::Add),
            EInstr::Rep(2),
            EInstr::Bin(BinOp::Mul),
        ];
        let tx = Tensor::f32(x.clone(), vec![m, inner]);
        let tb = Tensor::f32(bias.clone(), vec![inner]);
        let tc = Tensor::f32(col.clone(), vec![m]);
        let wide = kernel(prog.clone(), 3, Ty::F32, inner);
        let mut narrow = kernel(prog, 3, Ty::F32, inner);
        narrow.lanes = 1;
        let got = run_fused(&wide, &[&tx, &tb, &tc], &[m, inner]).unwrap();
        let want = run_fused(&narrow, &[&tx, &tb, &tc], &[m, inner]).unwrap();
        assert_eq!(got.f().unwrap(), want.f().unwrap());
        for i in 0..n {
            assert_eq!(got.f().unwrap()[i], (x[i] + bias[i % inner]) * col[i / inner]);
        }
        // Same equality on an unaligned sub-range.
        let wctx = FusedCtx::new(&wide, vec![Some(&tx), Some(&tb), Some(&tc)], n, &[]).unwrap();
        let nctx =
            FusedCtx::new(&narrow, vec![Some(&tx), Some(&tb), Some(&tc)], n, &[]).unwrap();
        let mut s = Scratch::new();
        let (lo, hi) = (7usize, n - 3);
        let wl = wctx.eval_block(lo, hi, &[], &mut s).unwrap();
        let nl = nctx.eval_block(lo, hi, &[], &mut s).unwrap();
        let (Lane::F(wv), Lane::F(nv)) = (&wl, &nl) else { panic!("lane type") };
        assert_eq!(wv, nv);
    }

    #[test]
    fn in_place_reuse_is_bitwise_equal_to_allocating() {
        // out = -(x) * y with x's buffer reused; compare vs run_fused.
        let n = BLOCK + 33;
        let x = f32s(n, 0.2);
        let y = f32s(n, 4.4);
        let k = kernel(
            vec![
                EInstr::Load(0),
                EInstr::Un(UnOp::Neg),
                EInstr::Load(1),
                EInstr::Bin(BinOp::Mul),
            ],
            2,
            Ty::F32,
            0,
        );
        let tx = Tensor::f32(x.clone(), vec![n]);
        let ty_ = Tensor::f32(y.clone(), vec![n]);
        let want = run_fused(&k, &[&tx, &ty_], &[n]).unwrap();
        let reuse = Tensor::f32(x, vec![n]);
        let got = run_fused_in_place(&k, vec![None, Some(&ty_)], 0, reuse, &[n]).unwrap();
        assert_eq!(got.f().unwrap(), want.f().unwrap());
    }

    #[test]
    fn in_place_refuses_shared_storage() {
        let k = kernel(vec![EInstr::Load(0), EInstr::Un(UnOp::Neg)], 1, Ty::F32, 0);
        let t = Tensor::f32(vec![1.0, 2.0], vec![2]);
        let alias = t.clone(); // shares the Arc
        assert!(!unique_storage(&t));
        assert!(run_fused_in_place(&k, vec![None], 0, t, &[2]).is_err());
        drop(alias);
    }

    #[test]
    fn fast_paths_match_blocked_execution() {
        let n = BLOCK + 7;
        let a = f32s(n, 0.5);
        let b = f32s(n, 1.5);
        let ta = Tensor::f32(a.clone(), vec![n]);
        let tb = Tensor::f32(b.clone(), vec![n]);
        let s = Tensor::f32(vec![2.5], vec![]);
        // unary
        let k1 = kernel(vec![EInstr::Load(0), EInstr::Un(UnOp::Tanh)], 1, Ty::F32, 0);
        let out = run_fused(&k1, &[&ta], &[n]).unwrap();
        for (&o, &x) in out.f().unwrap().iter().zip(&a) {
            assert_eq!(o, x.tanh());
        }
        // binary
        let k2 = kernel(
            vec![EInstr::Load(0), EInstr::Load(1), EInstr::Bin(BinOp::Sub)],
            2,
            Ty::F32,
            0,
        );
        let out = run_fused(&k2, &[&ta, &tb], &[n]).unwrap();
        for ((&o, &x), &y) in out.f().unwrap().iter().zip(&a).zip(&b) {
            assert_eq!(o, x - y);
        }
        // scalar on either side of a non-commutative op
        let k3 = kernel(
            vec![EInstr::Load(0), EInstr::Splat(1), EInstr::Bin(BinOp::Div)],
            2,
            Ty::F32,
            0,
        );
        let out = run_fused(&k3, &[&ta, &s], &[n]).unwrap();
        for (&o, &x) in out.f().unwrap().iter().zip(&a) {
            assert_eq!(o, x / 2.5);
        }
        let k4 = kernel(
            vec![EInstr::Splat(1), EInstr::Load(0), EInstr::Bin(BinOp::Div)],
            2,
            Ty::F32,
            0,
        );
        let out = run_fused(&k4, &[&ta, &s], &[n]).unwrap();
        for (&o, &x) in out.f().unwrap().iter().zip(&a) {
            assert_eq!(o, 2.5 / x);
        }
    }
}
