//! Structured run log: JSON-lines events for training runs.
//!
//! Every run can stream `{"t": seconds, "event": ..., ...}` records to a
//! file so loss curves and rate traces are machine-readable (the source
//! of truth behind EXPERIMENTS.md's end-to-end section). One line per
//! event; the file is append-only and crash-tolerant (each line is
//! self-contained).

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::Json;

pub struct EventLog {
    out: std::io::BufWriter<std::fs::File>,
    t0: Instant,
}

impl EventLog {
    pub fn create(path: &Path) -> Result<EventLog> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating event log {}", path.display()))?;
        Ok(EventLog { out: std::io::BufWriter::new(f), t0: Instant::now() })
    }

    /// Append one event. `fields` are merged into the record.
    pub fn emit(&mut self, event: &str, fields: &[(&str, Json)]) -> Result<()> {
        let mut m = BTreeMap::new();
        m.insert("t".to_string(), Json::Num(self.t0.elapsed().as_secs_f64()));
        m.insert("event".to_string(), Json::Str(event.to_string()));
        for (k, v) in fields {
            m.insert(k.to_string(), v.clone());
        }
        writeln!(self.out, "{}", Json::Obj(m).render())?;
        self.out.flush()?;
        Ok(())
    }

    pub fn step(&mut self, step: u64, loss: f32, rate: f64) -> Result<()> {
        self.emit(
            "step",
            &[
                ("step", Json::Num(step as f64)),
                ("loss", Json::Num(loss as f64)),
                ("rate", Json::Num(rate)),
            ],
        )
    }
}

/// Parse an event-log file back into records (analysis / tests).
pub fn read_events(path: &Path) -> Result<Vec<Json>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading event log {}", path.display()))?;
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Json::parse(l).map_err(|e| anyhow::anyhow!("bad event line: {e}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_events() {
        let dir = std::env::temp_dir().join(format!("pg-events-{}", std::process::id()));
        let path = dir.join("run.jsonl");
        {
            let mut log = EventLog::create(&path).unwrap();
            log.emit("run_start", &[("backend", Json::Str("gpu-opt".into()))]).unwrap();
            log.step(1, 0.98, 3500.0).unwrap();
            log.step(2, 0.95, 3600.0).unwrap();
            log.emit("run_end", &[("examples", Json::Num(32.0))]).unwrap();
        }
        let events = read_events(&path).unwrap();
        assert_eq!(events.len(), 4);
        assert_eq!(events[0].get("event").unwrap().as_str(), Some("run_start"));
        assert_eq!(events[1].get("step").unwrap().as_i64(), Some(1));
        assert!(events[1].get("loss").unwrap().as_f64().unwrap() < 1.0);
        // timestamps monotone
        let ts: Vec<f64> =
            events.iter().map(|e| e.get("t").unwrap().as_f64().unwrap()).collect();
        assert!(ts.windows(2).all(|w| w[1] >= w[0]));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn read_rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("pg-events-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.jsonl");
        std::fs::write(&path, "{\"ok\":1}\nnot json\n").unwrap();
        assert!(read_events(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
