//! Literal construction/extraction helpers over the `xla` crate, checked
//! against `TensorSpec`s from the manifest.

use anyhow::{bail, Context, Result};
use xla::Literal;

use super::manifest::{DType, TensorSpec};

/// Build an f32 literal of the given shape.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    if data.len() != n {
        bail!("lit_f32: {} elements for shape {shape:?}", data.len());
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    if shape.is_empty() {
        return Ok(Literal::scalar(data[0]));
    }
    Ok(Literal::vec1(data).reshape(&dims)?)
}

/// Build an i32 literal of the given shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    if data.len() != n {
        bail!("lit_i32: {} elements for shape {shape:?}", data.len());
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    if shape.is_empty() {
        return Ok(Literal::scalar(data[0]));
    }
    Ok(Literal::vec1(data).reshape(&dims)?)
}

pub fn scalar_f32(x: f32) -> Literal {
    Literal::scalar(x)
}

/// Extract an f32 vector (any shape, flattened).
pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("literal -> Vec<f32>")
}

pub fn to_vec_i32(lit: &Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>().context("literal -> Vec<i32>")
}

pub fn to_scalar_f32(lit: &Literal) -> Result<f32> {
    let v = to_vec_f32(lit)?;
    if v.len() != 1 {
        bail!("expected scalar, got {} elements", v.len());
    }
    Ok(v[0])
}

/// Validate a literal against a manifest tensor spec.
pub fn check_spec(lit: &Literal, spec: &TensorSpec) -> Result<()> {
    let shape = lit.array_shape().context("literal shape")?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    if dims != spec.shape {
        bail!("tensor {:?}: shape {dims:?} != spec {:?}", spec.name, spec.shape);
    }
    let ty = shape.ty();
    let ok = matches!(
        (spec.dtype, ty),
        (DType::F32, xla::ElementType::F32) | (DType::S32, xla::ElementType::S32)
    );
    if !ok {
        bail!("tensor {:?}: dtype {ty:?} != spec {}", spec.name, spec.dtype.name());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_round_trip() {
        let l = lit_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(to_vec_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let shape = l.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
    }

    #[test]
    fn i32_and_scalar() {
        let l = lit_i32(&[7, 8], &[2]).unwrap();
        assert_eq!(to_vec_i32(&l).unwrap(), vec![7, 8]);
        let s = scalar_f32(0.5);
        assert_eq!(to_scalar_f32(&s).unwrap(), 0.5);
    }

    #[test]
    fn size_mismatch_rejected() {
        assert!(lit_f32(&[1.0; 5], &[2, 3]).is_err());
        assert!(lit_i32(&[1; 7], &[2, 3]).is_err());
    }

    #[test]
    fn spec_check() {
        let spec = TensorSpec { name: "w".into(), dtype: DType::F32, shape: vec![2, 2] };
        let ok = lit_f32(&[0.0; 4], &[2, 2]).unwrap();
        assert!(check_spec(&ok, &spec).is_ok());
        let bad_shape = lit_f32(&[0.0; 4], &[4]).unwrap();
        assert!(check_spec(&bad_shape, &spec).is_err());
        let bad_ty = lit_i32(&[0; 4], &[2, 2]).unwrap();
        assert!(check_spec(&bad_ty, &spec).is_err());
    }
}
