//! Evaluator for parsed HLO modules.
//!
//! Straightforward SSA walk with one deliberate mechanism: operands are
//! passed **by move into their last consumer** (`Computation::last_use`),
//! so by the time `dynamic-update-slice` or `scatter` sees its operand the
//! `Rc` storage is usually uniquely owned and `Rc::make_mut` mutates in
//! place. The per-row embedding-update loops in the train-step artifacts
//! update a `[vocab, dim]` table once per row; without this they would
//! copy the whole table per row (O(rows·vocab·dim) per step), with it
//! they write `dim` floats (O(rows·dim)).
//!
//! Numeric policy: f32 arithmetic in source order. `reduce` accumulates
//! row-major from the init value; `scatter` applies updates row-major
//! over the updates array — the same order as the serial host baselines,
//! which is what makes the scatter artifacts bitwise-reproducible.

use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::parser::{BinOp, CmpDir, Instr, Module, Op, Shape, UnOp};
use super::value::{next_index, strides, Data, Tensor, Ty, Value};

/// Evaluate the module's ENTRY computation on `args` (indexed by
/// parameter number). Returns the root value.
pub fn eval_entry(m: &Module, args: Vec<Value>) -> Result<Value> {
    eval_comp(m, m.entry, args)
}

fn eval_comp(m: &Module, ci: usize, args: Vec<Value>) -> Result<Value> {
    let comp = &m.comps[ci];
    if args.len() != comp.n_params {
        bail!(
            "computation {:?}: {} arguments for {} parameters",
            comp.name,
            args.len(),
            comp.n_params
        );
    }
    let mut args: Vec<Option<Value>> = args.into_iter().map(Some).collect();
    let mut env: Vec<Option<Value>> = vec![None; comp.instrs.len()];
    for p in 0..comp.instrs.len() {
        let instr = &comp.instrs[p];
        let vals = resolve_operands(&mut env, instr, p, &comp.last_use)?;
        let v = eval_op(m, instr, vals, &mut args)
            .with_context(|| format!("{} (in {})", instr.name, comp.name))?;
        env[p] = Some(v);
    }
    env[comp.root].take().context("root value missing")
}

/// Fetch operand values, moving each out of the environment at its last
/// use (so uniquely-owned storage reaches mutating ops).
fn resolve_operands(
    env: &mut [Option<Value>],
    instr: &Instr,
    p: usize,
    last_use: &[usize],
) -> Result<Vec<Value>> {
    instr
        .operands
        .iter()
        .enumerate()
        .map(|(j, &o)| {
            let movable = last_use[o] == p && !instr.operands[j + 1..].contains(&o);
            let v = if movable { env[o].take() } else { env[o].clone() };
            v.with_context(|| format!("operand {o} of {} not evaluated", instr.name))
        })
        .collect()
}

fn eval_op(
    m: &Module,
    instr: &Instr,
    mut vals: Vec<Value>,
    args: &mut [Option<Value>],
) -> Result<Value> {
    Ok(match &instr.op {
        Op::Parameter(i) => args
            .get_mut(*i)
            .and_then(Option::take)
            .with_context(|| format!("missing argument {i}"))?,
        Op::Constant(t) => Value::Arr(t.clone()),
        Op::Iota { dim } => Value::Arr(iota(&instr.shape, *dim)?),
        Op::Broadcast { dims } => Value::Arr(broadcast(&instr.shape, vals[0].arr()?, dims)?),
        Op::Reshape => {
            let (_, out_dims) = instr.shape.arr()?;
            let mut t = vals.remove(0).into_arr()?;
            if t.elements() != out_dims.iter().product::<usize>() {
                bail!("reshape {:?} -> {:?}", t.dims, out_dims);
            }
            t.dims = out_dims.to_vec();
            Value::Arr(t)
        }
        Op::Convert => Value::Arr(convert(&instr.shape, vals[0].arr()?)?),
        Op::Transpose { perm } => Value::Arr(transpose(vals[0].arr()?, perm)?),
        Op::Compare { dir } => Value::Arr(compare(*dir, vals[0].arr()?, vals[1].arr()?)?),
        Op::Select => Value::Arr(select(vals[0].arr()?, vals[1].arr()?, vals[2].arr()?)?),
        Op::Binary(op) => Value::Arr(binary(*op, vals[0].arr()?, vals[1].arr()?)?),
        Op::Unary(op) => Value::Arr(unary(*op, vals[0].arr()?)?),
        Op::Dot { lc, rc } => Value::Arr(dot(vals[0].arr()?, vals[1].arr()?, *lc, *rc)?),
        Op::Reduce { dims, to_apply } => {
            Value::Arr(reduce(m, vals[0].arr()?, vals[1].arr()?, dims, *to_apply)?)
        }
        Op::Concat { dim } => {
            let parts: Vec<&Tensor> =
                vals.iter().map(|v| v.arr()).collect::<Result<_>>()?;
            Value::Arr(concat(&instr.shape, &parts, *dim)?)
        }
        Op::DynamicSlice { sizes } => {
            let starts = scalar_starts(&vals[1..])?;
            Value::Arr(dynamic_slice(vals[0].arr()?, &starts, sizes)?)
        }
        Op::DynamicUpdateSlice => {
            let starts = scalar_starts(&vals[2..])?;
            let upd = vals[1].arr()?.clone();
            let base = vals.swap_remove(0).into_arr()?;
            Value::Arr(dynamic_update_slice(base, &upd, &starts)?)
        }
        Op::Gather(g) => Value::Arr(gather(&instr.shape, vals[0].arr()?, vals[1].arr()?, g)?),
        Op::Scatter(s) => {
            let indices = vals[1].arr()?.clone();
            let updates = vals[2].arr()?.clone();
            let base = vals.swap_remove(0).into_arr()?;
            Value::Arr(scatter(m, base, &indices, &updates, s)?)
        }
        Op::Call { to_apply } => eval_comp(m, *to_apply, vals)?,
        Op::While { condition, body } => {
            let mut carry = vals.remove(0);
            loop {
                let c = eval_comp(m, *condition, vec![carry.clone()])?;
                if !c.arr()?.scalar_pred()? {
                    break;
                }
                carry = eval_comp(m, *body, vec![carry])?;
            }
            carry
        }
        Op::Tuple => Value::Tuple(vals),
        Op::GetTupleElement { index } => match vals.remove(0) {
            Value::Tuple(els) => els
                .into_iter()
                .nth(*index)
                .with_context(|| format!("tuple has no element {index}"))?,
            Value::Arr(_) => bail!("get-tuple-element on an array"),
        },
    })
}

fn scalar_starts(vals: &[Value]) -> Result<Vec<i64>> {
    vals.iter().map(|v| Ok(v.arr()?.scalar_i32()? as i64)).collect()
}

// ---------------------------------------------------------------- simple ops

fn iota(shape: &Shape, dim: usize) -> Result<Tensor> {
    let (ty, dims) = shape.arr()?;
    let n: usize = dims.iter().product();
    let st = strides(dims);
    let coord = |flat: usize| (flat / st[dim]) % dims[dim];
    Ok(match ty {
        Ty::S32 => Tensor::i32((0..n).map(|f| coord(f) as i32).collect(), dims.to_vec()),
        Ty::F32 => Tensor::f32((0..n).map(|f| coord(f) as f32).collect(), dims.to_vec()),
        Ty::Pred => bail!("iota over pred"),
    })
}

fn broadcast(shape: &Shape, src: &Tensor, map: &[usize]) -> Result<Tensor> {
    let (_, out_dims) = shape.arr()?;
    if map.len() != src.dims.len() {
        bail!("broadcast dims {:?} for operand rank {}", map, src.dims.len());
    }
    fn bc<T: Copy>(src: &[T], src_dims: &[usize], map: &[usize], out_dims: &[usize]) -> Vec<T> {
        let n: usize = out_dims.iter().product();
        if src.len() == 1 {
            return vec![src[0]; n];
        }
        let sst = strides(src_dims);
        let mut out = Vec::with_capacity(n);
        let mut idx = vec![0usize; out_dims.len()];
        if n == 0 {
            return out;
        }
        loop {
            let mut s = 0usize;
            for (j, &od) in map.iter().enumerate() {
                s += idx[od] * sst[j];
            }
            out.push(src[s]);
            if !next_index(&mut idx, out_dims) {
                break;
            }
        }
        out
    }
    let dims = out_dims.to_vec();
    Ok(match &src.data {
        Data::F32(v) => Tensor::f32(bc(v.as_slice(), &src.dims, map, out_dims), dims),
        Data::I32(v) => Tensor::i32(bc(v.as_slice(), &src.dims, map, out_dims), dims),
        Data::Pred(v) => Tensor::pred(bc(v.as_slice(), &src.dims, map, out_dims), dims),
    })
}

fn convert(shape: &Shape, src: &Tensor) -> Result<Tensor> {
    let (ty, dims) = shape.arr()?;
    let dims = dims.to_vec();
    Ok(match (ty, &src.data) {
        (Ty::F32, Data::Pred(v)) => {
            Tensor::f32(v.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect(), dims)
        }
        (Ty::F32, Data::I32(v)) => Tensor::f32(v.iter().map(|&x| x as f32).collect(), dims),
        (Ty::F32, Data::F32(v)) => Tensor::f32(v.to_vec(), dims),
        (Ty::S32, Data::F32(v)) => Tensor::i32(v.iter().map(|&x| x as i32).collect(), dims),
        (Ty::S32, Data::Pred(v)) => {
            Tensor::i32(v.iter().map(|&b| i32::from(b)).collect(), dims)
        }
        (Ty::S32, Data::I32(v)) => Tensor::i32(v.to_vec(), dims),
        (Ty::Pred, _) => bail!("convert to pred unsupported"),
    })
}

fn transpose(src: &Tensor, perm: &[usize]) -> Result<Tensor> {
    if perm.len() != src.dims.len() {
        bail!("transpose perm {:?} for rank {}", perm, src.dims.len());
    }
    let out_dims: Vec<usize> = perm.iter().map(|&p| src.dims[p]).collect();
    fn tr<T: Copy>(src: &[T], src_dims: &[usize], perm: &[usize], out_dims: &[usize]) -> Vec<T> {
        let sst = strides(src_dims);
        let n: usize = out_dims.iter().product();
        let mut out = Vec::with_capacity(n);
        let mut idx = vec![0usize; out_dims.len()];
        if n == 0 {
            return out;
        }
        loop {
            let mut s = 0usize;
            for (i, &p) in perm.iter().enumerate() {
                s += idx[i] * sst[p];
            }
            out.push(src[s]);
            if !next_index(&mut idx, out_dims) {
                break;
            }
        }
        out
    }
    let d = out_dims.clone();
    Ok(match &src.data {
        Data::F32(v) => Tensor::f32(tr(v.as_slice(), &src.dims, perm, &out_dims), d),
        Data::I32(v) => Tensor::i32(tr(v.as_slice(), &src.dims, perm, &out_dims), d),
        Data::Pred(v) => Tensor::pred(tr(v.as_slice(), &src.dims, perm, &out_dims), d),
    })
}

fn same_dims(a: &Tensor, b: &Tensor) -> Result<()> {
    if a.dims != b.dims {
        bail!("shape mismatch {:?} vs {:?}", a.dims, b.dims);
    }
    Ok(())
}

fn compare(dir: CmpDir, a: &Tensor, b: &Tensor) -> Result<Tensor> {
    same_dims(a, b)?;
    fn cmp<T: PartialOrd + PartialEq + Copy>(dir: CmpDir, a: &[T], b: &[T]) -> Vec<bool> {
        a.iter()
            .zip(b)
            .map(|(&x, &y)| match dir {
                CmpDir::Eq => x == y,
                CmpDir::Ne => x != y,
                CmpDir::Lt => x < y,
                CmpDir::Le => x <= y,
                CmpDir::Gt => x > y,
                CmpDir::Ge => x >= y,
            })
            .collect()
    }
    let out = match (&a.data, &b.data) {
        (Data::F32(x), Data::F32(y)) => cmp(dir, x.as_slice(), y.as_slice()),
        (Data::I32(x), Data::I32(y)) => cmp(dir, x.as_slice(), y.as_slice()),
        _ => bail!("compare dtype mismatch"),
    };
    Ok(Tensor::pred(out, a.dims.clone()))
}

fn select(pred: &Tensor, on_true: &Tensor, on_false: &Tensor) -> Result<Tensor> {
    same_dims(pred, on_true)?;
    same_dims(on_true, on_false)?;
    let p = pred.p()?;
    fn sel<T: Copy>(p: &[bool], t: &[T], f: &[T]) -> Vec<T> {
        p.iter().zip(t.iter().zip(f)).map(|(&c, (&x, &y))| if c { x } else { y }).collect()
    }
    let dims = on_true.dims.clone();
    Ok(match (&on_true.data, &on_false.data) {
        (Data::F32(t), Data::F32(f)) => Tensor::f32(sel(p, t.as_slice(), f.as_slice()), dims),
        (Data::I32(t), Data::I32(f)) => Tensor::i32(sel(p, t.as_slice(), f.as_slice()), dims),
        (Data::Pred(t), Data::Pred(f)) => {
            Tensor::pred(sel(p, t.as_slice(), f.as_slice()), dims)
        }
        _ => bail!("select dtype mismatch"),
    })
}

fn binary(op: BinOp, a: &Tensor, b: &Tensor) -> Result<Tensor> {
    same_dims(a, b)?;
    let dims = a.dims.clone();
    Ok(match (&a.data, &b.data) {
        (Data::F32(x), Data::F32(y)) => {
            let f: fn(f32, f32) -> f32 = match op {
                BinOp::Add => |a, b| a + b,
                BinOp::Sub => |a, b| a - b,
                BinOp::Mul => |a, b| a * b,
                BinOp::Div => |a, b| a / b,
                BinOp::Max => f32::max,
                BinOp::Min => f32::min,
                BinOp::And | BinOp::Or => bail!("logical op on f32"),
            };
            Tensor::f32(x.iter().zip(y.iter()).map(|(&a, &b)| f(a, b)).collect(), dims)
        }
        (Data::I32(x), Data::I32(y)) => {
            let f: fn(i32, i32) -> i32 = match op {
                BinOp::Add => i32::wrapping_add,
                BinOp::Sub => i32::wrapping_sub,
                BinOp::Mul => i32::wrapping_mul,
                BinOp::Div => |a, b| if b == 0 { 0 } else { a.wrapping_div(b) },
                BinOp::Max => i32::max,
                BinOp::Min => i32::min,
                BinOp::And | BinOp::Or => bail!("logical op on s32"),
            };
            Tensor::i32(x.iter().zip(y.iter()).map(|(&a, &b)| f(a, b)).collect(), dims)
        }
        (Data::Pred(x), Data::Pred(y)) => {
            let f: fn(bool, bool) -> bool = match op {
                BinOp::And => |a, b| a && b,
                BinOp::Or => |a, b| a || b,
                _ => bail!("arithmetic op on pred"),
            };
            Tensor::pred(x.iter().zip(y.iter()).map(|(&a, &b)| f(a, b)).collect(), dims)
        }
        _ => bail!("binary dtype mismatch"),
    })
}

fn unary(op: UnOp, a: &Tensor) -> Result<Tensor> {
    let dims = a.dims.clone();
    Ok(match (&a.data, op) {
        (Data::F32(x), _) => {
            let f: fn(f32) -> f32 = match op {
                UnOp::Neg => |v| -v,
                UnOp::Tanh => f32::tanh,
                UnOp::Exp => f32::exp,
                UnOp::Log => f32::ln,
            };
            Tensor::f32(x.iter().map(|&v| f(v)).collect(), dims)
        }
        (Data::I32(x), UnOp::Neg) => {
            Tensor::i32(x.iter().map(|&v| v.wrapping_neg()).collect(), dims)
        }
        _ => bail!("unary {op:?} on {}", a.data.ty().name()),
    })
}

fn dot(a: &Tensor, b: &Tensor, lc: usize, rc: usize) -> Result<Tensor> {
    if a.dims.len() != 2 || b.dims.len() != 2 {
        bail!("dot: only rank-2 operands supported ({:?} x {:?})", a.dims, b.dims);
    }
    let k = a.dims[lc];
    if b.dims[rc] != k {
        bail!("dot: contracting {k} vs {}", b.dims[rc]);
    }
    let m = a.dims[1 - lc];
    let n = b.dims[1 - rc];
    let af = a.f()?;
    let bf = b.f()?;
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        let row = &mut out[i * n..(i + 1) * n];
        for kk in 0..k {
            let av = if lc == 1 { af[i * k + kk] } else { af[kk * m + i] };
            if rc == 0 {
                let brow = &bf[kk * n..(kk + 1) * n];
                for (o, &bv) in row.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            } else {
                for (j, o) in row.iter_mut().enumerate() {
                    *o += av * bf[j * k + kk];
                }
            }
        }
    }
    Ok(Tensor::f32(out, vec![m, n]))
}

fn concat(shape: &Shape, parts: &[&Tensor], dim: usize) -> Result<Tensor> {
    let (_, out_dims) = shape.arr()?;
    let inner: usize = out_dims[dim + 1..].iter().product();
    let outer: usize = out_dims[..dim].iter().product();
    fn cat<'a, T: Copy>(
        slices: &[(&'a [T], usize)],
        outer: usize,
        inner: usize,
    ) -> Vec<T> {
        let total: usize = slices.iter().map(|(s, _)| s.len()).sum();
        let mut out = Vec::with_capacity(total);
        for o in 0..outer {
            for (s, dim_len) in slices {
                let chunk = dim_len * inner;
                out.extend_from_slice(&s[o * chunk..(o + 1) * chunk]);
            }
        }
        out
    }
    let dims = out_dims.to_vec();
    Ok(match &parts[0].data {
        Data::F32(_) => {
            let slices: Vec<(&[f32], usize)> =
                parts.iter().map(|t| Ok((t.f()?, t.dims[dim]))).collect::<Result<_>>()?;
            Tensor::f32(cat(&slices, outer, inner), dims)
        }
        Data::I32(_) => {
            let slices: Vec<(&[i32], usize)> =
                parts.iter().map(|t| Ok((t.i()?, t.dims[dim]))).collect::<Result<_>>()?;
            Tensor::i32(cat(&slices, outer, inner), dims)
        }
        Data::Pred(_) => {
            let slices: Vec<(&[bool], usize)> =
                parts.iter().map(|t| Ok((t.p()?, t.dims[dim]))).collect::<Result<_>>()?;
            Tensor::pred(cat(&slices, outer, inner), dims)
        }
    })
}

// ------------------------------------------------------------ slicing ops

fn clamp_start(start: i64, dim: usize, size: usize) -> usize {
    start.clamp(0, (dim - size) as i64) as usize
}

fn dynamic_slice(src: &Tensor, starts: &[i64], sizes: &[usize]) -> Result<Tensor> {
    if starts.len() != src.dims.len() || sizes.len() != src.dims.len() {
        bail!("dynamic-slice rank mismatch");
    }
    let s0: Vec<usize> = starts
        .iter()
        .zip(&src.dims)
        .zip(sizes)
        .map(|((&st, &d), &sz)| {
            if sz > d {
                bail!("slice size {sz} > dim {d}");
            }
            Ok(clamp_start(st, d, sz))
        })
        .collect::<Result<_>>()?;
    // Fast path: full-width trailing dims make the slice contiguous.
    let contiguous = !src.dims.is_empty() && src.dims[1..] == sizes[1..];
    fn slice_t<T: Copy>(
        src: &[T],
        src_dims: &[usize],
        start: &[usize],
        sizes: &[usize],
        contiguous: bool,
    ) -> Vec<T> {
        if contiguous {
            let inner: usize = src_dims[1..].iter().product();
            return src[start[0] * inner..(start[0] + sizes[0]) * inner].to_vec();
        }
        let sst = strides(src_dims);
        let n: usize = sizes.iter().product();
        let mut out = Vec::with_capacity(n);
        let mut idx = vec![0usize; sizes.len()];
        if n == 0 {
            return out;
        }
        loop {
            let flat: usize =
                idx.iter().zip(start).zip(&sst).map(|((&i, &s), &st)| (i + s) * st).sum();
            out.push(src[flat]);
            if !next_index(&mut idx, sizes) {
                break;
            }
        }
        out
    }
    let dims = sizes.to_vec();
    let c = contiguous;
    Ok(match &src.data {
        Data::F32(v) => Tensor::f32(slice_t(v.as_slice(), &src.dims, &s0, sizes, c), dims),
        Data::I32(v) => Tensor::i32(slice_t(v.as_slice(), &src.dims, &s0, sizes, c), dims),
        Data::Pred(v) => Tensor::pred(slice_t(v.as_slice(), &src.dims, &s0, sizes, c), dims),
    })
}

fn dynamic_update_slice(mut base: Tensor, upd: &Tensor, starts: &[i64]) -> Result<Tensor> {
    if starts.len() != base.dims.len() || upd.dims.len() != base.dims.len() {
        bail!("dynamic-update-slice rank mismatch");
    }
    let s0: Vec<usize> = starts
        .iter()
        .zip(&base.dims)
        .zip(&upd.dims)
        .map(|((&st, &d), &u)| {
            if u > d {
                bail!("update dim {u} > operand dim {d}");
            }
            Ok(clamp_start(st, d, u))
        })
        .collect::<Result<_>>()?;
    let contiguous = !base.dims.is_empty() && base.dims[1..] == upd.dims[1..];
    fn write_t<T: Copy>(
        dst: &mut [T],
        dst_dims: &[usize],
        upd: &[T],
        upd_dims: &[usize],
        start: &[usize],
        contiguous: bool,
    ) {
        if contiguous {
            let inner: usize = dst_dims[1..].iter().product();
            let off = start[0] * inner;
            dst[off..off + upd.len()].copy_from_slice(upd);
            return;
        }
        let dst_st = strides(dst_dims);
        let mut idx = vec![0usize; upd_dims.len()];
        if upd.is_empty() {
            return;
        }
        let mut u = 0usize;
        loop {
            let flat: usize =
                idx.iter().zip(start).zip(&dst_st).map(|((&i, &s), &st)| (i + s) * st).sum();
            dst[flat] = upd[u];
            u += 1;
            if !next_index(&mut idx, upd_dims) {
                break;
            }
        }
    }
    let bd = base.dims.clone();
    let ud = &upd.dims;
    match (&mut base.data, &upd.data) {
        (Data::F32(dst), Data::F32(u)) => {
            write_t(Rc::make_mut(dst).as_mut_slice(), &bd, u.as_slice(), ud, &s0, contiguous)
        }
        (Data::I32(dst), Data::I32(u)) => {
            write_t(Rc::make_mut(dst).as_mut_slice(), &bd, u.as_slice(), ud, &s0, contiguous)
        }
        (Data::Pred(dst), Data::Pred(u)) => {
            write_t(Rc::make_mut(dst).as_mut_slice(), &bd, u.as_slice(), ud, &s0, contiguous)
        }
        _ => bail!("dynamic-update-slice dtype mismatch"),
    }
    Ok(base)
}

// -------------------------------------------------------- gather / scatter

/// Read an s32 index from `indices` at batch coords `batch`, component
/// `j` along `index_vector_dim` (which may equal the rank, meaning the
/// index vectors are implicit scalars).
fn read_index(indices: &Tensor, batch: &[usize], ivd: usize, j: usize) -> Result<i64> {
    let st = strides(&indices.dims);
    let mut flat = 0usize;
    let mut b = 0usize;
    for d in 0..indices.dims.len() {
        let c = if d == ivd { j } else { let c = batch[b]; b += 1; c };
        flat += c * st[d];
    }
    Ok(indices.i()?[flat] as i64)
}

fn gather(
    shape: &Shape,
    operand: &Tensor,
    indices: &Tensor,
    g: &super::parser::GatherDims,
) -> Result<Tensor> {
    let (_, out_dims) = shape.arr()?;
    let od = &operand.dims;
    let batch_out_dims: Vec<usize> =
        (0..out_dims.len()).filter(|d| !g.offset_dims.contains(d)).collect();
    let operand_offset_dims: Vec<usize> =
        (0..od.len()).filter(|d| !g.collapsed_slice_dims.contains(d)).collect();
    if operand_offset_dims.len() != g.offset_dims.len() {
        bail!("gather: offset dims mismatch");
    }
    if g.slice_sizes.len() != od.len() {
        bail!("gather: slice_sizes rank mismatch");
    }
    for (d, (&sz, &dim)) in g.slice_sizes.iter().zip(od).enumerate() {
        if sz > dim {
            bail!("gather: slice size {sz} > operand dim {dim} (dim {d})");
        }
    }
    let ost = strides(od);
    let n: usize = out_dims.iter().product();
    fn run<T: Copy>(
        src: &[T],
        n: usize,
        out_dims: &[usize],
        mut at: impl FnMut(&[usize]) -> Result<usize>,
    ) -> Result<Vec<T>> {
        let mut out = Vec::with_capacity(n);
        let mut idx = vec![0usize; out_dims.len()];
        if n == 0 {
            return Ok(out);
        }
        loop {
            out.push(src[at(&idx)?]);
            if !next_index(&mut idx, out_dims) {
                break;
            }
        }
        Ok(out)
    }
    let mut batch = vec![0usize; batch_out_dims.len()];
    let mut at = |idx: &[usize]| -> Result<usize> {
        for (b, &d) in batch_out_dims.iter().enumerate() {
            batch[b] = idx[d];
        }
        let mut flat = 0usize;
        // Clamped slice starts along the mapped operand dims.
        for (j, &om) in g.start_index_map.iter().enumerate() {
            let raw = read_index(indices, &batch, g.index_vector_dim, j)?;
            flat += clamp_start(raw, od[om], g.slice_sizes[om]) * ost[om];
        }
        // Offsets within the slice along the non-collapsed dims.
        for (k, &odim) in operand_offset_dims.iter().enumerate() {
            flat += idx[g.offset_dims[k]] * ost[odim];
        }
        Ok(flat)
    };
    let dims = out_dims.to_vec();
    Ok(match &operand.data {
        Data::F32(v) => Tensor::f32(run(v.as_slice(), n, out_dims, &mut at)?, dims),
        Data::I32(v) => Tensor::i32(run(v.as_slice(), n, out_dims, &mut at)?, dims),
        Data::Pred(v) => Tensor::pred(run(v.as_slice(), n, out_dims, &mut at)?, dims),
    })
}

/// How a two-parameter computation combines (lhs = accumulated/original,
/// rhs = incoming). The artifacts only ever use `add` (accumulate) and
/// `return rhs` (overwrite); anything else falls back to full evaluation.
enum Combiner {
    Bin(BinOp),
    First,
    Second,
    Generic(usize),
}

fn classify_combiner(m: &Module, ci: usize) -> Combiner {
    let comp = &m.comps[ci];
    let root = &comp.instrs[comp.root];
    let param_no = |pos: usize| match comp.instrs[pos].op {
        Op::Parameter(i) => Some(i),
        _ => None,
    };
    match &root.op {
        Op::Parameter(0) => Combiner::First,
        Op::Parameter(1) => Combiner::Second,
        Op::Binary(b)
            if matches!(
                b,
                BinOp::Add | BinOp::Mul | BinOp::Max | BinOp::Min | BinOp::And | BinOp::Or
            ) && root.operands.len() == 2
                && param_no(root.operands[0]) == Some(0)
                && param_no(root.operands[1]) == Some(1)
                && comp.instrs.len() == 3 =>
        {
            Combiner::Bin(*b)
        }
        _ => Combiner::Generic(ci),
    }
}

fn scatter(
    m: &Module,
    mut base: Tensor,
    indices: &Tensor,
    updates: &Tensor,
    s: &super::parser::ScatterDims,
) -> Result<Tensor> {
    let od = base.dims.clone();
    let ud = updates.dims.clone();
    let batch_upd_dims: Vec<usize> =
        (0..ud.len()).filter(|d| !s.update_window_dims.contains(d)).collect();
    let operand_window_dims: Vec<usize> =
        (0..od.len()).filter(|d| !s.inserted_window_dims.contains(d)).collect();
    if operand_window_dims.len() != s.update_window_dims.len() {
        bail!("scatter: window dims mismatch");
    }
    let ost = strides(&od);
    let combiner = classify_combiner(m, s.to_apply);
    let mut batch = vec![0usize; batch_upd_dims.len()];
    let n: usize = ud.iter().product();

    // Destination flat index for one update element, or None when the
    // write lands out of bounds (XLA drops such updates).
    let mut dest = |idx: &[usize]| -> Result<Option<usize>> {
        for (b, &d) in batch_upd_dims.iter().enumerate() {
            batch[b] = idx[d];
        }
        let mut coord = vec![0i64; od.len()];
        for (j, &sd) in s.scatter_dims_to_operand_dims.iter().enumerate() {
            coord[sd] = read_index(indices, &batch, s.index_vector_dim, j)?;
        }
        for (k, &owd) in operand_window_dims.iter().enumerate() {
            coord[owd] += idx[s.update_window_dims[k]] as i64;
        }
        let mut flat = 0usize;
        for (d, &c) in coord.iter().enumerate() {
            if c < 0 || c as usize >= od[d] {
                return Ok(None);
            }
            flat += c as usize * ost[d];
        }
        Ok(Some(flat))
    };

    match (&mut base.data, &updates.data) {
        (Data::F32(dst), Data::F32(upd)) => {
            let dst = Rc::make_mut(dst);
            let mut idx = vec![0usize; ud.len()];
            let mut u = 0usize;
            if n > 0 {
                loop {
                    if let Some(flat) = dest(&idx)? {
                        match &combiner {
                            Combiner::Bin(BinOp::Add) => dst[flat] += upd[u],
                            Combiner::Bin(BinOp::Mul) => dst[flat] *= upd[u],
                            Combiner::Bin(BinOp::Max) => dst[flat] = dst[flat].max(upd[u]),
                            Combiner::Bin(BinOp::Min) => dst[flat] = dst[flat].min(upd[u]),
                            Combiner::Second => dst[flat] = upd[u],
                            Combiner::First => {}
                            Combiner::Bin(_) | Combiner::Generic(_) => {
                                dst[flat] =
                                    combine_generic_f32(m, &combiner, dst[flat], upd[u])?
                            }
                        }
                    }
                    u += 1;
                    if !next_index(&mut idx, &ud) {
                        break;
                    }
                }
            }
        }
        (Data::I32(dst), Data::I32(upd)) => {
            let dst = Rc::make_mut(dst);
            let mut idx = vec![0usize; ud.len()];
            let mut u = 0usize;
            if n > 0 {
                loop {
                    if let Some(flat) = dest(&idx)? {
                        match &combiner {
                            Combiner::Bin(BinOp::Add) => {
                                dst[flat] = dst[flat].wrapping_add(upd[u])
                            }
                            Combiner::Second => dst[flat] = upd[u],
                            Combiner::First => {}
                            _ => bail!("unsupported s32 scatter combiner"),
                        }
                    }
                    u += 1;
                    if !next_index(&mut idx, &ud) {
                        break;
                    }
                }
            }
        }
        _ => bail!("scatter dtype mismatch"),
    }
    Ok(base)
}

fn combine_generic_f32(m: &Module, c: &Combiner, a: f32, b: f32) -> Result<f32> {
    let Combiner::Generic(ci) = c else { bail!("not a generic combiner") };
    let out = eval_comp(
        m,
        *ci,
        vec![
            Value::Arr(Tensor::f32(vec![a], vec![])),
            Value::Arr(Tensor::f32(vec![b], vec![])),
        ],
    )?;
    Ok(out.arr()?.f()?[0])
}

// ---------------------------------------------------------------- reduce

fn reduce(
    m: &Module,
    src: &Tensor,
    init: &Tensor,
    rdims: &[usize],
    to_apply: usize,
) -> Result<Tensor> {
    let out_dims: Vec<usize> = src
        .dims
        .iter()
        .enumerate()
        .filter(|(d, _)| !rdims.contains(d))
        .map(|(_, &s)| s)
        .collect();
    let out_st = strides(&out_dims);
    // Per-source-dim stride into the output (0 for reduced dims).
    let mut map = vec![0usize; src.dims.len()];
    let mut o = 0usize;
    for d in 0..src.dims.len() {
        if !rdims.contains(&d) {
            map[d] = out_st[o];
            o += 1;
        }
    }
    let n_out: usize = out_dims.iter().product();
    let combiner = classify_combiner(m, to_apply);

    fn run<T: Copy>(
        src: &[T],
        src_dims: &[usize],
        map: &[usize],
        init: T,
        n_out: usize,
        mut f: impl FnMut(T, T) -> Result<T>,
    ) -> Result<Vec<T>> {
        let mut out = vec![init; n_out];
        let mut idx = vec![0usize; src_dims.len()];
        if src.is_empty() {
            return Ok(out);
        }
        let mut s = 0usize;
        loop {
            let dst: usize = idx.iter().zip(map).map(|(&i, &m)| i * m).sum();
            out[dst] = f(out[dst], src[s])?;
            s += 1;
            if !next_index(&mut idx, src_dims) {
                break;
            }
        }
        Ok(out)
    }

    Ok(match (&src.data, &init.data) {
        (Data::F32(v), Data::F32(i0)) => {
            let data = match &combiner {
                Combiner::Bin(BinOp::Add) => {
                    run(v.as_slice(), &src.dims, &map, i0[0], n_out, |a, b| Ok(a + b))?
                }
                Combiner::Bin(BinOp::Mul) => {
                    run(v.as_slice(), &src.dims, &map, i0[0], n_out, |a, b| Ok(a * b))?
                }
                Combiner::Bin(BinOp::Max) => {
                    run(v.as_slice(), &src.dims, &map, i0[0], n_out, |a, b| Ok(a.max(b)))?
                }
                Combiner::Bin(BinOp::Min) => {
                    run(v.as_slice(), &src.dims, &map, i0[0], n_out, |a, b| Ok(a.min(b)))?
                }
                c => run(v.as_slice(), &src.dims, &map, i0[0], n_out, |a, b| {
                    combine_generic_f32(m, c, a, b)
                })?,
            };
            Tensor::f32(data, out_dims)
        }
        (Data::I32(v), Data::I32(i0)) => {
            let data = match &combiner {
                Combiner::Bin(BinOp::Add) => {
                    run(v.as_slice(), &src.dims, &map, i0[0], n_out, |a, b| Ok(a.wrapping_add(b)))?
                }
                Combiner::Bin(BinOp::Max) => {
                    run(v.as_slice(), &src.dims, &map, i0[0], n_out, |a, b| Ok(a.max(b)))?
                }
                Combiner::Bin(BinOp::Min) => {
                    run(v.as_slice(), &src.dims, &map, i0[0], n_out, |a, b| Ok(a.min(b)))?
                }
                _ => bail!("unsupported s32 reduce combiner"),
            };
            Tensor::i32(data, out_dims)
        }
        (Data::Pred(v), Data::Pred(i0)) => {
            let data = match &combiner {
                Combiner::Bin(BinOp::And) => {
                    run(v.as_slice(), &src.dims, &map, i0[0], n_out, |a, b| Ok(a && b))?
                }
                Combiner::Bin(BinOp::Or) => {
                    run(v.as_slice(), &src.dims, &map, i0[0], n_out, |a, b| Ok(a || b))?
                }
                _ => bail!("unsupported pred reduce combiner"),
            };
            Tensor::pred(data, out_dims)
        }
        _ => bail!("reduce init dtype mismatch"),
    })
}
