//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Covers the full JSON grammar needed by `artifacts/manifest.json` and the
//! metrics/checkpoint metadata this repo writes: objects, arrays, strings
//! with standard escapes (incl. `\uXXXX`), numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- accessors ------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that fails loudly with the key name — manifest loading wants
    /// hard errors, not silent defaults.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or(JsonError { msg: format!("missing key {key:?}"), pos: 0 })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9e15 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- serializer -----------------------------------------------------

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance one UTF-8 scalar
                    let start = self.i;
                    let mut end = start + 1;
                    while end < self.b.len() && (self.b[end] & 0xC0) == 0x80 {
                        end += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "version": 1,
          "artifacts": [
            {"name": "t", "batch": 16, "inputs": [{"dtype": "f32", "shape": [20480, 64]}]},
            {"name": "u", "ok": true, "x": null}
          ]
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("version").unwrap().as_i64(), Some(1));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 2);
        assert_eq!(arts[0].get("batch").unwrap().as_usize(), Some(16));
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(20480));
        assert_eq!(arts[1].get("x"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes_round_trip() {
        let j = Json::Str("a\"b\\c\nd\tµ".into());
        let r = j.render();
        assert_eq!(Json::parse(&r).unwrap(), j);
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""µm""#).unwrap();
        assert_eq!(j.as_str(), Some("µm"));
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(Json::parse("42").unwrap().as_i64(), Some(42));
        assert_eq!(Json::parse("4.5").unwrap().as_i64(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn render_round_trip_nested() {
        let mut m = BTreeMap::new();
        m.insert("a".into(), Json::Arr(vec![Json::Num(1.0), Json::Bool(false)]));
        m.insert("b".into(), Json::Null);
        let j = Json::Obj(m);
        assert_eq!(Json::parse(&j.render()).unwrap(), j);
    }

    #[test]
    fn req_reports_missing_key() {
        let j = Json::parse("{}").unwrap();
        assert!(j.req("nope").is_err());
    }
}
