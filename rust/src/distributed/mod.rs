//! Downpour-style asynchronous distributed SGD — the paper's §5 future
//! work ("use the distributed algorithms for calculating gradients …
//! outlined by Jeffrey Dean et al. [10] … updates not being synchronized").
//!
//! Architecture (Dean et al. 2012, scaled to one machine):
//!
//! ```text
//!   ParameterServer (sharded RwLocks over the five tensors)
//!        ▲  push(Grads)           │ pull(snapshot, version)
//!        │                        ▼
//!   worker 0 … worker N-1   (each walks its own corpus shard, computes
//!                            gradients on a *stale* parameter copy, and
//!                            pushes without synchronization)
//! ```
//!
//! Workers compute gradients with the pure-Rust model
//! (`baselines::RefModel::grads`) — the same math the PJRT artifacts
//! execute (cross-checked in rust/tests/integration.rs) — so the
//! experiment isolates exactly what the paper asks about: does
//! *asynchrony* help this model? The bench (`cargo bench -- e9`) sweeps
//! worker counts and staleness and reports throughput + time-to-converge.

pub mod psserver;
pub mod worker;

pub use psserver::ParameterServer;
pub use worker::{run_downpour, DownpourConfig, DownpourReport};
