//! Dynamic request batching for the scoring path.
//!
//! Concurrent SCORE requests are coalesced into one dispatch: the executor
//! waits up to `max_wait_ms` for up to `max_batch` requests, executes, and
//! fans the scores back out. Classic dynamic batching — latency is bounded
//! by the wait budget, throughput grows with concurrency.
//!
//! Two scoring engines sit behind the same batching loop:
//!
//! * **Artifact** — pads the batch to a `forward_b{B}` artifact and
//!   executes it (one dispatch per coalesced batch) on the runtime's
//!   selected backend — PJRT or the HLO interpreter.
//! * **Host** — `baselines::RefModel` scoring on the checkpoint
//!   parameters. Selected automatically when no artifacts directory is
//!   present, so `polyglot serve` works even without `make artifacts`.

use std::path::Path;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::baselines::model_ref::{ModelParams, RefModel};
use crate::config::ServerCfg;
use crate::coordinator::upload_params;
use crate::runtime::{lit_i32, to_vec_f32, Executable, Runtime};

use super::protocol::Response;

pub struct ScoreRequest {
    pub window: Vec<i32>,
    pub reply: Sender<Response>,
}

enum Scorer {
    Artifact {
        // SAFETY of lifetime: exe borrows backend state inside rt; keep
        // rt boxed alongside for the executor's lifetime.
        _rt: Box<Runtime>,
        exe: std::rc::Rc<Executable>,
        params: Vec<xla::Literal>,
    },
    Host {
        params: ModelParams,
        /// Reusable forward-pass scratch (RefModel exists to avoid
        /// per-call allocation; keep one for the serving hot path).
        model: RefModel,
    },
}

pub struct BatchExecutor {
    scorer: Scorer,
    /// Batch the backing engine executes (artifact batch for the artifact
    /// scorer; the configured max for the host engine).
    pub artifact_batch: usize,
    window: usize,
    max_batch: usize,
    max_wait: Duration,
}

impl BatchExecutor {
    pub fn new(artifacts_dir: &Path, cfg: &ServerCfg, params: ModelParams) -> Result<Self> {
        let window = params.window;
        match Self::try_artifact(artifacts_dir, cfg, &params) {
            Ok((scorer, artifact_batch)) => Ok(BatchExecutor {
                scorer,
                artifact_batch,
                window,
                max_batch: cfg.max_batch.min(artifact_batch).max(1),
                max_wait: Duration::from_millis(cfg.max_wait_ms),
            }),
            Err(e) => {
                eprintln!(
                    "[server] artifact scoring unavailable ({e:#}); serving with the host model"
                );
                let model = RefModel::new(&params);
                Ok(BatchExecutor {
                    scorer: Scorer::Host { params, model },
                    artifact_batch: cfg.max_batch.max(1),
                    window,
                    max_batch: cfg.max_batch.max(1),
                    max_wait: Duration::from_millis(cfg.max_wait_ms),
                })
            }
        }
    }

    fn try_artifact(
        artifacts_dir: &Path,
        cfg: &ServerCfg,
        params: &ModelParams,
    ) -> Result<(Scorer, usize)> {
        let rt = Box::new(Runtime::new(artifacts_dir)?);
        // pick the smallest forward artifact that covers max_batch
        let mut batches = rt.manifest.batches_for("forward", None);
        batches.sort_unstable();
        let artifact_batch = batches
            .iter()
            .copied()
            .find(|&b| b >= cfg.max_batch)
            .or_else(|| batches.last().copied())
            .context("no forward artifacts in manifest")?;
        let name = format!("forward_b{artifact_batch}");
        let exe = rt.load(&name)?;
        let lits = upload_params(params)?;
        Ok((Scorer::Artifact { _rt: rt, exe, params: lits }, artifact_batch))
    }

    /// Collect up to `max_batch` requests (waiting at most `max_wait` after
    /// the first), execute one dispatch, reply. Returns the number of
    /// requests served (0 on idle timeout).
    pub fn run_once(&mut self, rx: &Receiver<ScoreRequest>) -> Result<usize> {
        // block briefly for the first request so the loop can poll stop flags
        let first = match rx.recv_timeout(Duration::from_millis(20)) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => return Ok(0),
            Err(RecvTimeoutError::Disconnected) => return Ok(0),
        };
        let mut reqs = vec![first];
        // Coalescing only pays when it amortizes a device dispatch; the
        // host scorer answers per-request, so it skips the wait instead of
        // taxing every lone request with max_wait_ms of latency.
        if matches!(self.scorer, Scorer::Artifact { .. }) {
            let deadline = Instant::now() + self.max_wait;
            while reqs.len() < self.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(r) => reqs.push(r),
                    Err(_) => break,
                }
            }
        }
        let n = reqs.len();
        match &mut self.scorer {
            Scorer::Artifact { exe, params, .. } => {
                // XLA's gather clamps out-of-range ids, so the padded
                // batch dispatch is safe as-is.
                let b = self.artifact_batch;
                let mut flat = vec![0i32; b * self.window]; // PAD = 0 padding
                for (i, r) in reqs.iter().enumerate() {
                    flat[i * self.window..(i + 1) * self.window].copy_from_slice(&r.window);
                }
                let windows = lit_i32(&flat, &[b, self.window])?;
                let inputs: Vec<&xla::Literal> = params.iter().chain([&windows]).collect();
                let out = exe.run(&inputs)?;
                let scores = to_vec_f32(&out[0])?;
                for (i, r) in reqs.into_iter().enumerate() {
                    let _ = r.reply.send(Response::Score(scores[i]));
                }
            }
            Scorer::Host { params, model } => {
                // The host model indexes the embedding table directly, so
                // ids must be validated here (the protocol layer only
                // rejects negatives) — a bad request answers ERR instead
                // of panicking the executor thread.
                let vocab = params.vocab as i32;
                for r in reqs {
                    let resp = if r.window.iter().any(|&i| i < 0 || i >= vocab) {
                        Response::Error(format!("window id out of range 0..{vocab}"))
                    } else {
                        Response::Score(model.scores(params, &r.window)[0])
                    };
                    let _ = r.reply.send(resp);
                }
            }
        }
        Ok(n)
    }
}
