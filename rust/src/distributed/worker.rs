//! Downpour workers + the experiment driver.
//!
//! Each worker owns a corpus shard and loops: every `pull_every` batches it
//! refreshes its stale parameter copy from the server; each batch it
//! computes gradients *against the stale copy* and pushes them. A separate
//! evaluator thread watches the server's live parameters for convergence.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::baselines::model_ref::{ModelParams, RefModel};
use crate::data::negative::NegativeSampler;
use crate::data::windows::WindowIter;
use crate::eval::ConvergenceTracker;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct DownpourConfig {
    pub workers: usize,
    pub batch: usize,
    pub lr: f32,
    /// Batches between parameter pulls (1 = near-synchronous; larger =
    /// staler workers).
    pub pull_every: usize,
    /// Total examples to process across all workers.
    pub example_budget: u64,
    pub converge_threshold: f32,
    pub seed: u64,
}

impl Default for DownpourConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            batch: 16,
            lr: 0.1,
            pull_every: 4,
            example_budget: 200_000,
            converge_threshold: 0.6,
            seed: 0xD0DE,
        }
    }
}

#[derive(Clone, Debug)]
pub struct DownpourReport {
    pub workers: usize,
    pub examples: u64,
    pub wall: Duration,
    pub rate: f64,
    pub pushes: u64,
    pub final_loss: f32,
    pub converged_examples: Option<u64>,
    pub converged_wall: Option<Duration>,
}

/// Run a Downpour experiment over pre-sharded, id-encoded sentences.
pub fn run_downpour(
    init: ModelParams,
    shards: Vec<Vec<Vec<u32>>>,
    cfg: &DownpourConfig,
) -> Result<DownpourReport> {
    use super::psserver::ParameterServer;
    assert_eq!(shards.len(), cfg.workers, "one shard per worker");
    let window = init.window;
    let vocab = init.vocab;

    // Held-out eval batch built from REAL corpus windows (random-id pairs
    // would measure nothing: the hinge on garbage-vs-garbage stays ~1).
    let eval_batch = {
        let shard0 = shards[0].clone();
        let mut it = WindowIter::new(&shard0, window);
        let mut rng = Rng::new(cfg.seed ^ 0xEEE);
        let sampler = NegativeSampler::uniform(vocab);
        let mut win = vec![0i32; window];
        let mut windows = Vec::with_capacity(256 * window);
        let mut centers = Vec::with_capacity(256);
        for _ in 0..256 {
            centers.push(it.next_window(&mut win));
            windows.extend_from_slice(&win);
        }
        let mut corrupt = Vec::new();
        sampler.sample_batch(&mut rng, &centers, &mut corrupt);
        (windows, corrupt)
    };

    let ps = Arc::new(ParameterServer::new(init, cfg.lr));
    let stop = Arc::new(AtomicBool::new(false));
    let examples_done = Arc::new(AtomicU64::new(0));

    let t0 = Instant::now();
    let handles: Vec<_> = shards
        .into_iter()
        .enumerate()
        .map(|(wi, shard)| {
            let ps = Arc::clone(&ps);
            let stop = Arc::clone(&stop);
            let examples_done = Arc::clone(&examples_done);
            let cfg = cfg.clone();
            std::thread::Builder::new()
                .name(format!("downpour-{wi}"))
                .spawn(move || {
                    let mut params = ps.pull();
                    let mut model = RefModel::new(&params);
                    let mut it = WindowIter::new(&shard, window);
                    let sampler = NegativeSampler::uniform(vocab);
                    let mut rng = Rng::new(cfg.seed ^ (0x1234 + wi as u64));
                    let mut win = vec![0i32; window];
                    let mut windows = Vec::with_capacity(cfg.batch * window);
                    let mut centers = Vec::with_capacity(cfg.batch);
                    let mut corrupt = Vec::with_capacity(cfg.batch);
                    let mut batches = 0usize;
                    while !stop.load(Ordering::Relaxed) {
                        if batches % cfg.pull_every == 0 {
                            params = ps.pull(); // refresh stale copy
                        }
                        windows.clear();
                        centers.clear();
                        for _ in 0..cfg.batch {
                            centers.push(it.next_window(&mut win));
                            windows.extend_from_slice(&win);
                        }
                        sampler.sample_batch(&mut rng, &centers, &mut corrupt);
                        let (_loss, grads) = model.grads(&params, &windows, &corrupt);
                        ps.push(&grads);
                        batches += 1;
                        let done = examples_done
                            .fetch_add(cfg.batch as u64, Ordering::Relaxed)
                            + cfg.batch as u64;
                        if done >= cfg.example_budget {
                            stop.store(true, Ordering::Relaxed);
                        }
                    }
                    batches as u64
                })
                .expect("spawn worker")
        })
        .collect();

    // Evaluator: track convergence of the *live* server parameters on the
    // held-out batch.
    let mut tracker = ConvergenceTracker::new(cfg.converge_threshold);
    let mut final_loss = f32::NAN;
    let mut converged_examples = None;
    let mut converged_wall = None;
    while !stop.load(Ordering::Relaxed) {
        std::thread::sleep(Duration::from_millis(15));
        let snap = ps.pull();
        let mut m = RefModel::new(&snap);
        let loss = m.loss(&snap, &eval_batch.0, &eval_batch.1);
        final_loss = loss;
        let ex = examples_done.load(Ordering::Relaxed);
        if tracker.update(loss, 0, ex, t0.elapsed()) {
            let c = tracker.converged().unwrap();
            converged_examples = Some(c.examples);
            converged_wall = Some(c.wall);
        }
    }
    let mut pushes = 0u64;
    for h in handles {
        pushes += h.join().expect("worker panicked");
    }
    let wall = t0.elapsed();
    let examples = examples_done.load(Ordering::Relaxed);
    Ok(DownpourReport {
        workers: cfg.workers,
        examples,
        wall,
        rate: examples as f64 / wall.as_secs_f64(),
        pushes,
        final_loss,
        converged_examples,
        converged_wall,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generator, CorpusSpec};
    use crate::data::shard::split_shards;
    use crate::text::Vocab;

    fn corpus_shards(n: usize, vocab_cap: usize) -> (Vec<Vec<Vec<u32>>>, usize) {
        let c = generator::generate(&CorpusSpec {
            languages: 2,
            tokens_per_language: 12_000,
            lexicon: 600,
            threads: 2,
            ..CorpusSpec::default()
        });
        let vocab = Vocab::build(c.sentences.iter().map(|s| s.as_slice()), 1, vocab_cap);
        let encoded: Vec<Vec<u32>> = c.sentences.iter().map(|s| vocab.encode(s)).collect();
        (split_shards(encoded, n, 3), vocab.len())
    }

    #[test]
    fn single_worker_downpour_learns() {
        let (shards, vlen) = corpus_shards(1, 1024);
        // vocab == corpus vocab so the held-out eval draws trained rows
        let init = ModelParams::init(vlen, 8, 5, 8, 5);
        let cfg = DownpourConfig {
            workers: 1,
            lr: 0.08,
            example_budget: 60_000,
            converge_threshold: 0.95,
            ..DownpourConfig::default()
        };
        let rep = run_downpour(init, shards, &cfg).unwrap();
        assert!(rep.examples >= 60_000);
        assert!(rep.final_loss < 0.95, "loss {}", rep.final_loss);
        assert!(rep.pushes > 0);
    }

    #[test]
    fn multi_worker_downpour_stays_finite_and_learns() {
        let (shards, vlen) = corpus_shards(4, 1024);
        let init = ModelParams::init(vlen, 8, 5, 8, 5);
        let cfg = DownpourConfig {
            workers: 4,
            lr: 0.08,
            pull_every: 8, // aggressively stale
            example_budget: 80_000,
            converge_threshold: 0.95,
            ..DownpourConfig::default()
        };
        let rep = run_downpour(init, shards, &cfg).unwrap();
        assert!(rep.final_loss.is_finite());
        assert!(rep.final_loss < 0.95, "async training diverged: {}", rep.final_loss);
    }

    #[test]
    #[should_panic(expected = "one shard per worker")]
    fn shard_count_mismatch_panics() {
        let (shards, _) = corpus_shards(2, 512);
        let init = ModelParams::init(512, 4, 5, 4, 1);
        let cfg = DownpourConfig { workers: 3, ..DownpourConfig::default() };
        let _ = run_downpour(init, shards, &cfg);
    }
}
